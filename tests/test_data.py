"""Data pipeline: generators (determinism, planted semantics), signature store,
metrics (exact AUC), neighbor sampler."""
from __future__ import annotations

import numpy as np
import pytest

try:  # optional dev dep: only the property-based tests need it
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core.minhash import jaccard_from_sets
from repro.core.signatures import (build_signature_store, densify_store,
                                   synthetic_dense_store,
                                   synthetic_signature_store)
from repro.data.graph import NeighborSampler, molecule_batch, pad_block, sbm_graph
from repro.data.lm_data import LMGenerator
from repro.data.metrics import StreamingEval, accuracy, logloss, roc_auc
from repro.data.synthetic_ctr import CTRGenerator, CTRSpec, DINGenerator, DINSpec


# ------------------------------------------------------------------ CTR data

def test_ctr_batches_deterministic_and_seekable():
    gen = CTRGenerator(CTRSpec(n_fields=6, n_dense=3, seed=1))
    a = gen.batch(64, 5)
    b = gen.batch(64, 5)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
    c = gen.batch(64, 6)
    assert (a["sparse"] != c["sparse"]).any()


def test_ctr_schema_and_ranges():
    spec = CTRSpec(n_fields=6, n_dense=3, seed=2)
    gen = CTRGenerator(spec)
    b = gen.batch(128, 0)
    assert b["dense"].shape == (128, 3) and b["dense"].dtype == np.float32
    assert b["sparse"].shape == (128, 6) and b["sparse"].dtype == np.int32
    for f, v in enumerate(spec.vocab_sizes):
        assert b["sparse"][:, f].min() >= 0
        assert b["sparse"][:, f].max() < v
    rate = b["label"].mean()
    assert 0.1 < rate < 0.9


def test_ctr_planted_jaccard_structure():
    """Cross-field same-cluster values co-occur -> higher Jaccard.

    With single-valued fields, two values of the SAME field never share a
    sample (disjoint D_v) — the paper's common-memory sharing materializes
    across fields: a sample of intent z picks cluster-z values in every field,
    so field-0/cluster-c values co-occur with field-1/cluster-c values.
    """
    spec = CTRSpec(n_fields=4, n_dense=2, n_clusters=4, p_signal=0.9, seed=3)
    gen = CTRGenerator(spec)
    store = build_signature_store(gen.rows_for_signatures(4000),
                                  spec.total_vocab, max_per_value=256)
    flat = np.asarray(store.flat)
    offs = np.asarray(store.offsets)
    lens = np.asarray(store.lengths)

    def value_set(gid):
        return set(flat[offs[gid]: offs[gid + 1]].tolist())

    v0, v1 = spec.vocab_sizes[0], spec.vocab_sizes[1]
    # most frequent value of each field
    top_f0 = int(np.argmax(lens[:v0]))
    top_f1_local = int(np.argmax(lens[v0: v0 + v1]))
    c0 = gen.value_cluster[0][top_f0]
    same, diff = [], []
    # compare field-0 top value against frequent field-1 values by cluster
    freq_f1 = np.argsort(-lens[v0: v0 + v1])[:40]
    for w in freq_f1:
        j = jaccard_from_sets(value_set(top_f0), value_set(v0 + int(w)))
        (same if gen.value_cluster[1][int(w)] == c0 else diff).append(j)
    assert same and diff
    # head values appear in 1000s of rows but D_v is capped at 256 sample ids,
    # so absolute Jaccard is diluted — the planted structure shows as a strong
    # RATIO between same- and cross-cluster pairs
    assert np.mean(same) > 3.0 * max(np.mean(diff), 1e-4), (
        np.mean(same), np.mean(diff))
    assert np.mean(same) > 0.004
    # same-field values are sample-disjoint by construction
    second_f0 = int(np.argsort(-lens[:v0])[1])
    assert jaccard_from_sets(value_set(top_f0), value_set(second_f0)) == 0.0


def test_din_batches():
    gen = DINGenerator(DINSpec(n_items=500, n_clusters=10, hist_len=20, seed=0))
    b = gen.batch(64, 0)
    assert b["hist"].shape == (64, 20)
    assert b["hist_mask"].dtype == bool
    assert set(np.unique(b["label"])) <= {0.0, 1.0}
    # labels carry signal: same-cluster candidates mostly positive
    assert 0.2 < b["label"].mean() < 0.8


# ------------------------------------------------------------ signature store

def test_build_signature_store_counts():
    rows = [np.array([0, 1]), np.array([1, 2]), np.array([0, 1, 2])]
    store = build_signature_store(rows, n_values=4)
    np.testing.assert_array_equal(np.asarray(store.lengths), [2, 3, 2, 0])
    flat = np.asarray(store.flat)
    offs = np.asarray(store.offsets)
    assert set(flat[offs[1]: offs[2]].tolist()) == {0, 1, 2}  # value 1's rows


def test_build_store_respects_n_samples_and_cap():
    rows = [np.array([0])] * 100
    store = build_signature_store(rows, n_values=1, max_per_value=8,
                                  n_samples=50)
    assert int(store.lengths[0]) == 8   # capped
    store2 = build_signature_store(rows, n_values=1, max_per_value=128,
                                   n_samples=50)
    assert int(store2.lengths[0]) == 50  # n_samples honored


def test_densify_matches_csr():
    store = synthetic_signature_store(n_values=50, n_clusters=5,
                                      samples_per_value=16, seed=0)
    dense = densify_store(store, max_set=16)
    flat, offs = np.asarray(store.flat), np.asarray(store.offsets)
    sets_np = np.asarray(dense.sets)
    for v in range(50):
        want = flat[offs[v]: offs[v] + 16]
        np.testing.assert_array_equal(sets_np[v, : len(want)], want)


def test_densify_row_padding():
    store = synthetic_signature_store(n_values=10, n_clusters=2,
                                      samples_per_value=4, seed=1)
    dense = densify_store(store, max_set=8, n_rows=16)
    assert dense.sets.shape == (16, 8)
    assert int(dense.lengths[12]) == 0  # padded rows are empty


def test_synthetic_dense_store_cluster_structure():
    d = synthetic_dense_store(n_values=40, n_clusters=4, max_set=16, seed=0)
    sets_np = np.asarray(d.sets)
    same = jaccard_from_sets(set(sets_np[0]), set(sets_np[4]))    # cluster 0
    diff = jaccard_from_sets(set(sets_np[0]), set(sets_np[1]))    # 0 vs 1
    assert same > 0.3 > diff == 0.0


# ---------------------------------------------------------------------- LM

def test_lm_generator_learnable_bigrams():
    gen = LMGenerator(vocab_size=256, seed=0)
    b = gen.batch(16, 32, 0)
    assert b["tokens"].shape == (16, 32)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
    # patterned successors appear: P(label == successor(token)) well above 1/V
    toks, labs = b["tokens"].ravel(), b["labels"].ravel()
    hit = (labs == gen.successor[toks]).mean()
    assert hit > 0.3


# ------------------------------------------------------------------- metrics

def _auc_brute(y, s):
    pos = s[y == 1]
    neg = s[y == 0]
    if len(pos) == 0 or len(neg) == 0:
        return 0.5
    cmp = (pos[:, None] > neg[None, :]).sum() + 0.5 * (
        pos[:, None] == neg[None, :]).sum()
    return cmp / (len(pos) * len(neg))


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(st.lists(st.tuples(st.booleans(),
                              st.integers(0, 20)), min_size=2, max_size=60))
    def test_property_auc_matches_brute_force(pairs):
        y = np.asarray([int(a) for a, _ in pairs], np.float64)
        s = np.asarray([b for _, b in pairs], np.float64) / 7.0  # force ties
        assert roc_auc(y, s) == pytest.approx(_auc_brute(y, s), abs=1e-9)
else:
    def test_property_auc_matches_brute_force():
        pytest.importorskip("hypothesis")


def test_auc_perfect_and_inverted():
    y = np.asarray([0, 0, 1, 1])
    assert roc_auc(y, np.asarray([0.1, 0.2, 0.8, 0.9])) == 1.0
    assert roc_auc(y, np.asarray([0.9, 0.8, 0.2, 0.1])) == 0.0
    assert roc_auc(y, np.asarray([0.5, 0.5, 0.5, 0.5])) == 0.5


def test_streaming_eval():
    ev = StreamingEval()
    rng = np.random.default_rng(0)
    all_y, all_s = [], []
    for _ in range(5):
        y = (rng.random(100) < 0.4).astype(np.float64)
        s = y * 1.5 + rng.normal(0, 1, 100)
        ev.add(y, s)
        all_y.append(y)
        all_s.append(s)
    out = ev.compute()
    want = roc_auc(np.concatenate(all_y), np.concatenate(all_s))
    assert out["auc"] == pytest.approx(want)
    assert out["n"] == 500
    assert 0 < out["logloss"] < 2


# ------------------------------------------------------------------- graphs

def test_sbm_graph_homophily():
    g = sbm_graph(n_nodes=400, n_edges=2000, d_feat=16, n_classes=4, seed=0,
                  homophily=0.9)
    same = (g.labels[g.src] == g.labels[g.dst]).mean()
    assert same > 0.6  # way above the 1/4 chance rate


def test_neighbor_sampler_block_validity():
    g = sbm_graph(n_nodes=300, n_edges=1500, d_feat=8, n_classes=3, seed=1)
    sampler = NeighborSampler(g, fanouts=(4, 3), seed=0)
    batch_nodes = np.arange(10)
    block = sampler.sample(batch_nodes)
    n = block["n_nodes"]
    assert block["src"].max() < n and block["dst"].max() < n
    assert block["features"].shape == (n, 8)
    # every batch node is present and labeled
    assert block["label_mask"].sum() == len(batch_nodes)
    # fanout respected: each hop adds at most fan * frontier edges
    assert len(block["src"]) <= 10 * 4 + 10 * 4 * 3 + n  # + self loops


def test_pad_block_shapes_stable():
    g = sbm_graph(n_nodes=200, n_edges=900, d_feat=8, n_classes=3, seed=2)
    sampler = NeighborSampler(g, fanouts=(3,), seed=0)
    shapes = set()
    for i in range(3):
        block = sampler.sample(np.arange(i * 5, i * 5 + 5))
        padded = pad_block(block, max_nodes=64, max_edges=128)
        shapes.add((padded["src"].shape, padded["features"].shape))
    assert len(shapes) == 1  # stable jit signature


def test_molecule_batch_block_diagonal():
    mb = molecule_batch(batch_size=4, n_nodes=6, n_edges=10, d_feat=8,
                        n_classes=3, seed=0)
    # edges never cross graph boundaries
    gid_src = mb["graph_ids"][mb["src"]]
    gid_dst = mb["graph_ids"][mb["dst"]]
    np.testing.assert_array_equal(gid_src, gid_dst)
    assert mb["labels"].shape == (4,)
