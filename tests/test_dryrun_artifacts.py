"""The 40-cell x 2-mesh dry-run must be complete and physically plausible.

These tests validate the persisted artifacts (experiments/dryrun/*.json); the
dry-run itself is run via `python -m repro.launch.sweep` (subprocess-isolated,
512 fake devices) and takes ~1-2 h for all 80 cells — re-running it inside the
unit-test suite would be wasteful, so the suite asserts on its outputs.
"""
from __future__ import annotations

import json
import os

import pytest

from repro.configs.base import get_config, list_archs

ART = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")

HBM_BYTES = 16 * 2**30          # TPU v5e: 16 GiB per chip

# Cells whose CPU-measured peak is dominated by XLA:CPU's bf16->f32
# normalization of irreducible bf16 activations (~2x inflation, ledgers in
# EXPERIMENTS.md §Dry-run), plus deepseek-v3 training, which genuinely needs
# more than 256/512 v5e chips (the real run used 2048 H800-80GB).  These are
# held to 2x the HBM budget (the measured inflation bound) instead of 1x.
CPU_INFLATED = {
    # 671B training at 256 chips also genuinely exceeds v5e HBM (3x):
    ("deepseek-v3-671b", "train_4k", "16x16"): 3,
    ("deepseek-v3-671b", "train_4k", "2x16x16"): 2,
    ("deepseek-v3-671b", "prefill_32k", "16x16"): 2,
    ("llama4-scout-17b-a16e", "train_4k", "16x16"): 2,
    ("qwen1.5-32b", "prefill_32k", "16x16"): 2,
    ("qwen1.5-32b", "prefill_32k", "2x16x16"): 2,
}


def _cells():
    out = []
    for arch_id in list_archs():
        if arch_id.startswith("lma-dlrm"):
            continue
        for shape in get_config(arch_id).shapes:
            out.append((arch_id, shape))
    return out


def _load(arch, shape, mesh):
    path = os.path.join(ART, f"{arch}__{shape}__{mesh}.json")
    assert os.path.exists(path), f"missing dry-run artifact {path}"
    with open(path) as f:
        return json.load(f)


def test_all_80_cells_present():
    cells = _cells()
    assert len(cells) == 40
    missing = []
    for arch, shape in cells:
        for mesh in ("16x16", "2x16x16"):
            p = os.path.join(ART, f"{arch}__{shape}__{mesh}.json")
            if not os.path.exists(p):
                missing.append((arch, shape, mesh))
    assert not missing, missing


@pytest.mark.parametrize("mesh", ["16x16", "2x16x16"])
@pytest.mark.parametrize("arch,shape", _cells())
def test_cell_artifact_sane(arch, shape, mesh):
    art = _load(arch, shape, mesh)
    assert art["chips"] == (512 if mesh == "2x16x16" else 256)
    assert art["cost"]["flops"] > 0
    assert art["cost"]["bytes_accessed"] > 0
    mem = art["memory"]
    budget = HBM_BYTES * CPU_INFLATED.get((arch, shape, mesh), 1)
    assert mem["peak_device_bytes"] < budget, (
        f"{arch}/{shape}@{mesh} does not fit HBM: "
        f"{mem['peak_device_bytes']/2**30:.2f} GiB (budget {budget/2**30:.0f})")
    assert mem["argument_bytes"] >= 0 and mem["temp_bytes"] >= 0


@pytest.mark.parametrize("arch,shape", [(a, s) for a, s in _cells()
                                        if s in ("train_4k", "train_batch",
                                                 "full_graph_sm")])
def test_training_cells_have_gradient_collectives(arch, shape):
    """Any data-parallel train step must all-reduce (or reduce-scatter) grads."""
    art = _load(arch, shape, "16x16")
    colls = art["collectives"]
    reduced = colls["all-reduce"]["count"] + colls["reduce-scatter"]["count"]
    assert reduced > 0, f"{arch}/{shape}: no gradient reduction in HLO"


def test_multi_pod_shards_the_pod_axis():
    """Multi-pod cells: per-device fraction of batch-bound work must shrink
    (512 vs 256 chips -> per-device FLOPs roughly halve for train cells)."""
    checked = 0
    for arch, shape in _cells():
        if not shape.startswith("train"):
            continue
        one = _load(arch, shape, "16x16")["cost"]["flops"]
        two = _load(arch, shape, "2x16x16")["cost"]["flops"]
        assert two < one * 0.75, (arch, shape, one, two)
        checked += 1
    assert checked >= 9  # 5 LM train_4k + 4 recsys train_batch


def test_recsys_artifacts_record_exchange_strategy():
    """Every recsys cell's meta carries the resolved exchange strategy and
    the modeled per-strategy bytes (repro.dist.exchange.resolve_exchange).
    The recorded strategy must be the argmin of the recorded cost table
    (meta and model may not contradict each other), and every lma cell must
    resolve to a chunked strategy — the D' set-reconstruction term
    (exchange_set_width) dominates even where the slab fits the fused VMEM
    budget, matching the measured 8-device bench where ring/all_to_all beat
    fused psum."""
    for arch in ("dlrm-rm2", "dcn-v2", "xdeepfm", "din"):
        for shape in ("train_batch", "serve_bulk", "serve_p99",
                      "retrieval_cand"):
            for mesh in ("16x16", "2x16x16"):
                meta = _load(arch, shape, mesh)["meta"]
                costs = meta["exchange_modeled_bytes"]
                assert set(costs) == {"psum", "ring", "all_to_all"}
                assert meta["exchange"] == min(costs, key=costs.get), \
                    (arch, shape, mesh, meta["exchange"], costs)
        got = _load(arch, "train_batch", "16x16")["meta"]["exchange"]
        assert got in ("ring", "all_to_all"), (arch, got)


def test_recsys_train_artifacts_record_sparse_update_costs():
    """Every recsys TRAIN cell's meta carries the per-path sparse-update
    cost table (repro.dist.exchange.sparse_update_cost) next to its
    sparse_grads flag, and flag and table may not contradict each other:
    sparse_grads is true exactly when the best sparse path models under the
    dense slab tax.  The bucket-eligible lma archs (dlrm-rm2, dcn-v2 —
    budget % dim == 0, striped layout) must record sparse_grads: true at
    pod scale — the flip the bucketed dedup was built for — while the
    ragged-budget archs (din, xdeepfm: m % d != 0, flat element records)
    stay dense under the O(K log K) sort."""
    for arch in ("dlrm-rm2", "dcn-v2", "xdeepfm", "din"):
        for mesh in ("16x16", "2x16x16"):
            meta = _load(arch, "train_batch", mesh)["meta"]
            costs = meta["sparse_update_modeled_bytes"]
            assert set(costs) == {"dense", "sparse_psum",
                                  "sparse_all_to_all", "dedup_sort"}
            best = min(costs["sparse_psum"], costs["sparse_all_to_all"])
            assert meta["sparse_grads"] == (best < costs["dense"]), \
                (arch, mesh, meta["sparse_grads"], costs)
            expect_sparse = arch in ("dlrm-rm2", "dcn-v2")
            assert meta["sparse_grads"] == expect_sparse, (arch, mesh, meta)


def test_recsys_train_artifacts_record_tier_split():
    """Every memory-family recsys train cell's meta carries the tiering
    posture it would launch with (repro.launch.steps._tier_meta): hot/cold
    split from the same ``tier_split`` rule the launcher applies, plus the
    modeled host-fetch bytes/step — except xdeepfm, whose dual memory pools
    the launcher refuses to tier, which must record the explicit skipped
    marker instead of a split it would never apply.  The committed cells
    lower with no per-device budget, so the recorded posture is all-hot
    with zero host traffic — and the split must still account for every
    pool slot.  The non-trivial branch (a budget smaller than the pool) is
    pinned here directly against the same helper the artifacts were
    lowered through."""
    from repro.embed import get_scheme
    from repro.launch.steps import _tier_meta

    for mesh in ("16x16", "2x16x16"):
        tier = _load("xdeepfm", "train_batch", mesh)["meta"]["tier"]
        assert tier == {"skipped": "dual memory pools stay resident"}, mesh

    for arch in ("dlrm-rm2", "dcn-v2", "din"):
        rcfg = get_config(arch).make_model("train_batch")
        e = rcfg.embedding
        m = get_scheme(e.kind).memory_slots(e)
        for mesh in ("16x16", "2x16x16"):
            tier = _load(arch, "train_batch", mesh)["meta"]["tier"]
            assert set(tier) == {"tier_budget_mb", "hot_rows", "cold_rows",
                                 "host_fetch_bytes_per_step"}
            assert tier["hot_rows"] + tier["cold_rows"] == m, (arch, mesh)
            assert tier["tier_budget_mb"] is None
            assert tier["cold_rows"] == 0
            assert tier["host_fetch_bytes_per_step"] == 0

    # the over-budget branch of the same helper: a 256 MB budget on the
    # 135M-slot (515 MB x 2 leaves) pool splits hot/cold and models real
    # host traffic; the budget covers both compact leaves AND their stage
    # regions (one block per location element, set width included), so the
    # hot slab gets strictly less than half of it.  B=64 with no mesh is
    # the launcher-scale posture (a pod-scale B divides over the mesh's
    # data axes first, like _exchange_meta's n_flat).
    rcfg = get_config("dlrm-rm2").make_model("train_batch")
    os.environ["REPRO_TIER_BUDGET_MB"] = "256"
    try:
        tier = _tier_meta(rcfg, 64)["tier"]
    finally:
        del os.environ["REPRO_TIER_BUDGET_MB"]
    m = get_scheme(rcfg.embedding.kind).memory_slots(rcfg.embedding)
    assert tier["tier_budget_mb"] == 256.0
    assert 0 < tier["hot_rows"] < 256 * 2**20 // 4 // 2
    assert tier["hot_rows"] + tier["cold_rows"] == m
    assert tier["host_fetch_bytes_per_step"] > 0


def test_lma_memory_traffic_is_activation_sized():
    """The paper-critical property: collective bytes for the recsys train cells
    stay activation-sized — independent of the 135M-slot memory budget."""
    for arch in ("dlrm-rm2", "dcn-v2", "xdeepfm", "din"):
        art = _load(arch, "train_batch", "16x16")
        coll = art["collectives"]["total_bytes"]
        # budget * 4 bytes would be ~0.5 GiB; activations are tens of MiB
        assert coll < 256 * 2**20, f"{arch}: {coll/2**20:.0f} MiB collectives"
