"""Hashing-substrate properties the LMA analysis relies on (DESIGN.md section 9):
uniform marginals, ~1/r pairwise collisions, independence across seed streams.
"""
from __future__ import annotations

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.hashing import (combine_chain, fmix32, hash_pair, hash_to_range,
                                hash_u32, seed_stream)


N = 1 << 16


def test_fmix32_is_bijective_sample():
    x = jnp.arange(N, dtype=jnp.uint32)
    y = np.asarray(fmix32(x))
    assert len(np.unique(y)) == N  # bijection => no collisions on any sample


def test_hash_u32_deterministic():
    x = jnp.arange(1024, dtype=jnp.uint32)
    s = seed_stream(42, 1)[0]
    a = np.asarray(hash_u32(x, s))
    b = np.asarray(hash_u32(x, s))
    np.testing.assert_array_equal(a, b)


def test_hash_u32_uniform_marginals():
    x = jnp.arange(N, dtype=jnp.uint32)
    for seed_i in range(3):
        s = seed_stream(7, 3)[seed_i]
        h = np.asarray(hash_u32(x, s))
        # 16 buckets on the top nibble; chi-square should be ~15 for uniform
        counts = np.bincount(h >> 28, minlength=16)
        expected = N / 16
        chi2 = float(np.sum((counts - expected) ** 2 / expected))
        assert chi2 < 60.0, chi2  # p ~ 1e-6 cutoff for 15 dof


@pytest.mark.parametrize("r", [97, 1024, 65536])
def test_hash_to_range_collision_rate(r):
    x = jnp.arange(20_000, dtype=jnp.uint32)
    s = seed_stream(3, 1)[0]
    h = np.asarray(hash_to_range(x, s, r))
    assert h.min() >= 0 and h.max() < r
    counts = np.bincount(h, minlength=r).astype(np.float64)
    # pairwise collision rate ~ 1/r
    n = len(x)
    p_coll = float(np.sum(counts * (counts - 1)) / (n * (n - 1)))
    assert abs(p_coll - 1.0 / r) < 3.0 / r


def test_seed_streams_distinct_and_independent():
    s = np.asarray(seed_stream(0, 256))
    assert len(np.unique(s)) == 256
    # hashes under different seeds should be uncorrelated
    x = jnp.arange(8192, dtype=jnp.uint32)
    h0 = np.asarray(hash_u32(x, jnp.uint32(s[0]))).astype(np.float64)
    h1 = np.asarray(hash_u32(x, jnp.uint32(s[1]))).astype(np.float64)
    rho = np.corrcoef(h0, h1)[0, 1]
    assert abs(rho) < 0.05, rho


def test_hash_pair_differs_in_both_args():
    s = seed_stream(1, 4)
    a = np.asarray(hash_pair(jnp.uint32(5), jnp.uint32(0), s[0]))
    b = np.asarray(hash_pair(jnp.uint32(5), jnp.uint32(1), s[0]))
    c = np.asarray(hash_pair(jnp.uint32(6), jnp.uint32(0), s[0]))
    d = np.asarray(hash_pair(jnp.uint32(5), jnp.uint32(0), s[1]))
    assert len({int(a), int(b), int(c), int(d)}) == 4


def test_combine_chain_order_sensitive_and_collision_free():
    s = seed_stream(9, 1)[0]
    parts = jnp.asarray(np.random.default_rng(0).integers(
        0, 2**32, (4096, 4), dtype=np.uint32))
    h = np.asarray(combine_chain(parts, s))
    swapped = parts[:, ::-1]
    h_swapped = np.asarray(combine_chain(swapped, s))
    # order matters (polynomial chain, not a symmetric fold)
    assert (h != h_swapped).mean() > 0.99
    # distinct tuples should essentially never collide
    assert len(np.unique(h)) > 4090


def test_combine_chain_deterministic_vs_equal_inputs():
    s = seed_stream(11, 1)[0]
    parts = jnp.asarray(np.arange(32, dtype=np.uint32).reshape(8, 4))
    a = np.asarray(combine_chain(parts, s))
    b = np.asarray(combine_chain(parts, s))
    np.testing.assert_array_equal(a, b)
