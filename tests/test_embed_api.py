"""The repro.embed scheme registry + EmbeddingTable facade.

API-stability contract: ``tests/golden/embed_api_golden.json`` was generated
by the PRE-refactor ``core.embedding`` implementation (same seeds); the new
registry-dispatched API must reproduce its param/buffer tree structure, leaf
shapes, AND leaf/output bytes exactly, and a PR-2-era checkpoint
(``tests/golden/pr2_checkpoint``) must restore through CheckpointManager
unchanged.
"""
from __future__ import annotations

import hashlib
import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.embed as E
from repro.core.allocation import LMAParams
from repro.core.memory import lookup
from repro.core.signatures import synthetic_dense_store
from repro.embed import (EmbeddingConfig, EmbeddingTable, get_scheme,
                         list_schemes, register_scheme, resolve_backend)
from repro.embed import backends as bke

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "embed_api_golden.json")
PR2_CKPT = os.path.join(os.path.dirname(__file__), "golden", "pr2_checkpoint")

SIX_KINDS = ("full", "hashed_elem", "hashed_row", "qr", "lma", "md")


def _sha(a) -> str:
    return hashlib.sha256(
        np.ascontiguousarray(np.asarray(a)).tobytes()).hexdigest()


def _golden():
    with open(GOLDEN) as f:
        return json.load(f)


def _golden_cfg(g, kind) -> EmbeddingConfig:
    base = dict(kind=kind, vocab_sizes=tuple(g["vocab_sizes"]), dim=g["dim"])
    if kind in ("hashed_elem", "hashed_row", "qr", "lma"):
        base["budget"] = g["budget"]
    if kind == "lma":
        base["lma"] = LMAParams(d=g["dim"], m=g["budget"],
                                n_h=g["lma"]["n_h"],
                                max_set=g["lma"]["max_set"])
    if kind == "md":
        base["md_dims"] = tuple(g["md_dims"])
    return EmbeddingConfig(**base)


def _golden_buffers(table: EmbeddingTable):
    if table.config.kind != "lma":
        return {}
    store = synthetic_dense_store(table.config.total_vocab, 12,
                                  max_set=table.config.lma.max_set, seed=1)
    return table.make_buffers(store)


def _golden_ids(g):
    rng = np.random.default_rng(g["ids_seed"])
    V = g["vocab_sizes"]
    ids2 = np.stack([rng.integers(0, v, 8) for v in V], 1).astype(np.int32)
    bag_ids = rng.integers(0, V[0], (6, 9)).astype(np.int32)
    bag_mask = rng.random((6, 9)) < 0.6
    return ids2, bag_ids, bag_mask


# ----------------------------------------------------- golden-pytree contract

@pytest.mark.parametrize("kind", SIX_KINDS)
def test_init_matches_pre_refactor_golden(kind):
    """EmbeddingTable.init(key) == pre-refactor init_embedding/make_buffers:
    identical key sets, leaf shapes, dtypes, and bytes."""
    g = _golden()
    gk = g["kinds"][kind]
    table = EmbeddingTable(_golden_cfg(g, kind))
    params = table.init(jax.random.key(0))
    bufs = _golden_buffers(table)
    assert sorted(params) == sorted(gk["params"])
    assert sorted(bufs) == sorted(gk["buffers"])
    for name, info in gk["params"].items():
        a = np.asarray(params[name])
        assert list(a.shape) == info["shape"], (kind, name)
        assert str(a.dtype) == info["dtype"], (kind, name)
        assert _sha(a) == info["sha256"], (kind, name, "param bytes changed")
    for name, info in gk["buffers"].items():
        a = np.asarray(bufs[name])
        assert list(a.shape) == info["shape"], (kind, name)
        assert _sha(a) == info["sha256"], (kind, name, "buffer bytes changed")
    assert table.param_count == gk["param_count"]


@pytest.mark.parametrize("kind", SIX_KINDS)
def test_outputs_match_pre_refactor_golden(kind):
    """embed / embed_fields / embed_bag bytes == the pre-refactor dispatch
    (including fused-engine routing where eligible)."""
    g = _golden()
    gk = g["kinds"][kind]
    table = EmbeddingTable(_golden_cfg(g, kind))
    params = table.init(jax.random.key(0))
    bufs = _golden_buffers(table)
    ids2, bag_ids, bag_mask = _golden_ids(g)
    assert _sha(table.embed(params, bufs, 0, jnp.asarray(ids2[:, 0]))) \
        == gk["embed_sha256"]
    assert _sha(table.embed_fields(params, bufs, jnp.asarray(ids2))) \
        == gk["embed_fields_sha256"]
    assert _sha(table.embed_bag(params, bufs, 0, jnp.asarray(bag_ids),
                                jnp.asarray(bag_mask), "sum")) \
        == gk["embed_bag_sum_sha256"]
    assert _sha(table.embed_bag(params, bufs, 0, jnp.asarray(bag_ids),
                                jnp.asarray(bag_mask), "mean")) \
        == gk["embed_bag_mean_sha256"]


def test_pr2_checkpoint_restores_unchanged():
    """A checkpoint written by the PR-2-era code restores through
    CheckpointManager and matches a fresh EmbeddingTable.init bit-for-bit
    (param pytree key names are a stable contract)."""
    from repro.checkpoint.manager import CheckpointManager
    g = _golden()
    mgr = CheckpointManager(PR2_CKPT)
    step, tree = mgr.restore()
    assert step == 60
    table = EmbeddingTable(_golden_cfg(g, "lma"))
    fresh = table.init(jax.random.key(0))
    assert sorted(tree["params"]["embedding"]) == sorted(fresh)
    for k in fresh:
        np.testing.assert_array_equal(np.asarray(tree["params"]["embedding"][k]),
                                      np.asarray(fresh[k]))
    bufs = _golden_buffers(table)
    for k in bufs:
        np.testing.assert_array_equal(np.asarray(tree["buffers"][k]),
                                      np.asarray(bufs[k]))
    # optimizer-moment tree mirrors the param tree (same suffixes)
    assert sorted(tree["opt"][0]["mu"]["embedding"]) == sorted(fresh)


# -------------------------------------------------------- registry / surface

def test_public_surface_resolves():
    for name in E.__all__:
        assert getattr(E, name, None) is not None, name


def test_every_scheme_describe_round_trips():
    """describe() must be JSON-serializable with the core keys present and
    consistent (the dryrun/bench introspection contract)."""
    for kind in list_schemes():
        cfg = get_scheme(kind).build_config((512, 256), 8, 4096)
        d = EmbeddingTable(cfg).describe()
        back = json.loads(json.dumps(d))
        assert back == d, kind
        for key in ("kind", "family", "param_count", "expansion_rate",
                    "dim", "n_tables", "total_vocab"):
            assert key in back, (kind, key)
        assert back["kind"] == kind
        assert back["family"] in ("memory", "table")
        assert back["param_count"] == cfg.param_count()


def test_every_scheme_builds_and_embeds():
    """Registry-driven config -> init -> embed for every registered scheme:
    the path embedding_of_kind and the bench sweep rely on."""
    for kind in list_schemes():
        scheme = get_scheme(kind)
        cfg = scheme.build_config((512, 256), 8, 4096)
        table = EmbeddingTable(cfg)
        params = table.init(jax.random.key(1))
        store = synthetic_dense_store(cfg.total_vocab, 8, max_set=32, seed=1) \
            if scheme.needs_signature_store else None
        bufs = table.make_buffers(store)
        out = table.embed(params, bufs, 0, jnp.asarray([0, 1, 511]))
        assert out.shape == (3, 8), kind
        assert np.isfinite(np.asarray(out)).all(), kind
        n = sum(int(np.prod(x.shape))
                for x in jax.tree_util.tree_leaves(params))
        assert n == table.param_count, kind


def test_unknown_scheme_error_lists_registered():
    with pytest.raises(KeyError, match="freq"):
        get_scheme("nope")


def test_register_scheme_requires_kind():
    with pytest.raises(TypeError):
        @register_scheme
        class Bad(E.Scheme):
            pass


def test_freq_registered_from_its_own_module():
    """The extensibility proof: the freq scheme lives outside the dispatch
    code — repro/embed/table.py, backends.py, and the built-in schemes.py
    contain zero freq logic (the registry only imports the module for
    discovery, like configs.base does for arch configs)."""
    import repro.embed.freq as freq_mod
    scheme = get_scheme("freq")
    assert type(scheme).__module__ == "repro.embed.freq"
    src = os.path.dirname(freq_mod.__file__)
    for core in ("table.py", "backends.py", "schemes.py"):
        assert "freq" not in open(os.path.join(src, core)).read(), core


# ----------------------------------------------------------- backend resolver

def _mem_cfg(kind="hashed_elem", budget=4096):
    return EmbeddingConfig(kind=kind, vocab_sizes=(512,), dim=8, budget=budget)


def test_resolver_split_when_engine_disabled():
    from repro.kernels.fused_embed import ops as fe
    cfg = _mem_cfg()
    params = EmbeddingTable(cfg).init(jax.random.key(0))
    old = fe.ENABLED
    fe.ENABLED = False
    try:
        assert resolve_backend(cfg, params) is bke.SPLIT
    finally:
        fe.ENABLED = old


def test_resolver_fused_when_eligible():
    cfg = _mem_cfg()
    params = EmbeddingTable(cfg).init(jax.random.key(0))
    assert resolve_backend(cfg, params) is bke.FUSED


def test_resolver_fused_rejects_pool_size_mismatch():
    """The engine indexes mod the spec's m: a truncated pool must fall back."""
    cfg = _mem_cfg()
    params = {"memory": jnp.zeros((cfg.budget - 1,), jnp.float32)}
    assert resolve_backend(cfg, params) is bke.SPLIT


def test_resolver_sharded_under_mesh():
    from repro.dist.context import use_mesh
    cfg = _mem_cfg()
    params = EmbeddingTable(cfg).init(jax.random.key(0))
    mesh = jax.make_mesh((1,), ("data",))
    with use_mesh(mesh):
        b = resolve_backend(cfg, params)
    assert isinstance(b, bke.ShardedBackend)


def test_resolver_none_for_table_family():
    cfg = EmbeddingConfig(kind="full", vocab_sizes=(64,), dim=8)
    params = EmbeddingTable(cfg).init(jax.random.key(0))
    assert resolve_backend(cfg, params) is None


def test_freq_never_fused():
    """freq publishes no FusedSpec: the resolver must pick the split oracle
    even at engine-friendly pool sizes."""
    cfg = _mem_cfg("freq")
    params = EmbeddingTable(cfg).init(jax.random.key(0))
    assert resolve_backend(cfg, params) is bke.SPLIT


# ------------------------------------------- satellite: lma init scale (Thm 2)

def test_lma_bernoulli_default_init_is_unit_scale():
    """Theorem 2's init: raw +/-1 entries (variance 1) when init_scale is
    None; the 1/sqrt(d) activation scale applies to the normal init only."""
    cfg = EmbeddingConfig(kind="lma", vocab_sizes=(512,), dim=16, budget=8192,
                          lma=LMAParams(d=16, m=8192, n_h=2, max_set=16),
                          memory_init="bernoulli")
    mem = np.asarray(EmbeddingTable(cfg).init(jax.random.key(0))["memory"])
    assert set(np.unique(mem)) == {-1.0, 1.0}
    assert mem.var() == pytest.approx(1.0, abs=0.05)

    cfg_n = EmbeddingConfig(kind="lma", vocab_sizes=(512,), dim=16,
                            budget=8192,
                            lma=LMAParams(d=16, m=8192, n_h=2, max_set=16),
                            memory_init="normal")
    mem_n = np.asarray(EmbeddingTable(cfg_n).init(jax.random.key(0))["memory"])
    assert mem_n.std() == pytest.approx(1.0 / np.sqrt(16), rel=0.1)


def test_lma_training_config_pins_activation_scale():
    """embedding_of_kind('lma', ...) keeps the explicit 1/sqrt(d) training
    scale (end-to-end conditioning unchanged vs the seed configs)."""
    from repro.configs._recsys_common import lma_embedding
    cfg = lma_embedding((512, 256), 16, expansion=4.0)
    assert cfg.memory_init == "bernoulli"
    assert cfg.init_scale == pytest.approx(1.0 / np.sqrt(16))
    mem = np.asarray(EmbeddingTable(cfg).init(jax.random.key(0))["memory"])
    assert mem.std() == pytest.approx(1.0 / np.sqrt(16), rel=0.05)


# ------------------------------------- satellite: honest expansion_rate alpha

def test_expansion_rate_uses_param_count_for_qr_md():
    g = _golden()
    for kind in ("qr", "md"):
        cfg = _golden_cfg(g, kind)
        expect = cfg.total_vocab * cfg.dim / cfg.param_count()
        assert cfg.expansion_rate == pytest.approx(expect), kind
    # qr's real footprint is below the nominal budget -> alpha must be HIGHER
    # than the old budget-based report (no more overstated compression)
    qr = _golden_cfg(g, "qr")
    assert qr.param_count() < qr.budget
    assert qr.expansion_rate > qr.total_vocab * qr.dim / qr.budget


def test_expansion_rate_budget_kinds_unchanged():
    g = _golden()
    for kind in ("hashed_elem", "hashed_row", "lma"):
        cfg = _golden_cfg(g, kind)
        assert cfg.expansion_rate == pytest.approx(
            cfg.total_vocab * cfg.dim / cfg.budget), kind
    assert _golden_cfg(g, "full").expansion_rate == pytest.approx(1.0)


# ------------------------------------------------------------ freq scheme

def _freq_cfg(budget=2048, hot_k=32, dim=8):
    return EmbeddingConfig(kind="freq", vocab_sizes=(300, 200), dim=dim,
                           budget=budget, seed=3,
                           options=(("hot_k", hot_k),))


def test_freq_hot_ids_get_dedicated_rows():
    cfg = _freq_cfg()
    scheme = get_scheme("freq")
    bufs = scheme.make_buffers(cfg)
    hot = np.asarray(bufs["freq_hot_ids"])
    np.testing.assert_array_equal(hot, np.arange(32))   # default head
    loc = np.asarray(scheme.locations(cfg, bufs, jnp.asarray(hot)))
    # rank r owns slots [r*d, (r+1)*d): collision-free, order-preserving
    want = hot[:, None] * cfg.dim + np.arange(cfg.dim)[None, :]
    np.testing.assert_array_equal(loc, want)


def test_freq_tail_ids_hash_into_tail_region():
    cfg = _freq_cfg()
    scheme = get_scheme("freq")
    bufs = scheme.make_buffers(cfg)
    tail_ids = jnp.asarray(np.arange(32, 500, dtype=np.int32))
    loc = np.asarray(scheme.locations(cfg, bufs, tail_ids))
    assert (loc >= 32 * cfg.dim).all()                   # never in the hot tier
    assert (loc < cfg.budget).all()
    # row-hashed: all d lanes of one id live in one contiguous row
    rows = (loc - 32 * cfg.dim) // cfg.dim
    assert (rows == rows[:, :1]).all()


def test_freq_counts_select_topk():
    cfg = _freq_cfg(hot_k=4)
    scheme = get_scheme("freq")
    counts = np.zeros(cfg.total_vocab, np.int64)
    counts[[7, 123, 400, 9]] = [100, 90, 80, 70]
    bufs = scheme.make_buffers(cfg, counts)
    np.testing.assert_array_equal(np.asarray(bufs["freq_hot_ids"]),
                                  [7, 9, 123, 400])


def test_freq_embed_matches_split_oracle():
    """EmbeddingTable.embed == lookup(memory, locations) bit-for-bit (freq
    has no fused path; the resolver must route to the split oracle)."""
    cfg = _freq_cfg()
    table = EmbeddingTable(cfg)
    params = table.init(jax.random.key(2))
    bufs = table.make_buffers()
    rng = np.random.default_rng(0)
    ids = jnp.asarray(rng.integers(0, 300, (64,), np.int32))
    got = np.asarray(table.embed(params, bufs, 0, ids))
    scheme = get_scheme("freq")
    want = np.asarray(lookup(params["memory"],
                             scheme.locations(cfg, bufs, ids)))
    np.testing.assert_array_equal(got, want)


def test_freq_gradient_flows_and_is_scatter_add():
    cfg = _freq_cfg()
    table = EmbeddingTable(cfg)
    params = table.init(jax.random.key(2))
    bufs = table.make_buffers()
    ids = jnp.asarray([0, 1, 299])

    def loss(p):
        return jnp.sum(table.embed(p, bufs, 0, ids))

    g = np.asarray(jax.grad(loss)(params)["memory"])
    assert g.sum() == pytest.approx(3 * cfg.dim)


def test_freq_in_registry_sweep_list():
    assert "freq" in list_schemes()


def test_freq_build_config_explicit_hot_k_wins():
    """An explicit hot_k kwarg must override a pre-existing options entry
    (cfg.opt returns the first match)."""
    scheme = get_scheme("freq")
    cfg = scheme.build_config((512,), 8, 4096, hot_k=64,
                              options=(("hot_k", 8),))
    assert scheme.hot_k(cfg) == 64


def test_buffer_specs_match_make_buffers():
    """Scheme.buffer_specs (the dryrun spec-only contract) must agree with
    the concrete make_buffers output: same keys, shapes, dtypes."""
    # lma: D' store rows padded to the launcher's row count
    g = _golden()
    lma_cfg = _golden_cfg(g, "lma")
    store = synthetic_dense_store(lma_cfg.total_vocab, 12,
                                  max_set=lma_cfg.lma.max_set, seed=1)
    concrete = get_scheme("lma").make_buffers(lma_cfg, store)
    specs = get_scheme("lma").buffer_specs(lma_cfg, int(store.sets.shape[0]))
    assert sorted(specs) == sorted(concrete)
    for name, (shape, dt) in specs.items():
        assert tuple(concrete[name].shape) == tuple(shape), name
        assert str(concrete[name].dtype) == dt, name
    # freq: hot-id table
    fcfg = _freq_cfg()
    concrete = get_scheme("freq").make_buffers(fcfg)
    specs = get_scheme("freq").buffer_specs(fcfg, 0)
    assert sorted(specs) == sorted(concrete)
    for name, (shape, dt) in specs.items():
        assert tuple(concrete[name].shape) == tuple(shape), name
        assert str(concrete[name].dtype) == dt, name
    # schemes without buffers stay spec-free
    assert get_scheme("full").buffer_specs(_golden_cfg(g, "full"), 0) == {}


def test_buffer_source_declarations():
    """Launchers key data prep on buffer_source; the built-ins declare it."""
    assert get_scheme("lma").buffer_source == "signatures"
    assert get_scheme("lma").needs_signature_store
    assert get_scheme("freq").buffer_source == "id_counts"
    for kind in ("full", "hashed_elem", "hashed_row", "qr", "md"):
        assert get_scheme(kind).buffer_source is None, kind


def test_freq_sharded_generic_path_matches_oracle():
    """Under a (2, 4) mesh the resolver hands freq the *generic*
    mask-local-gather (no bespoke sharded_lookup); forward must stay
    bit-identical to the single-device oracle.  Subprocess keeps this
    process's device count at 1 (same pattern as tests/test_sharded.py)."""
    import subprocess
    import sys
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
from repro.core.memory import lookup
from repro.dist.context import use_mesh
from repro.embed import EmbeddingConfig, EmbeddingTable, get_scheme
from repro.embed import backends as bke

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = EmbeddingConfig(kind="freq", vocab_sizes=(300, 200), dim=16,
                      budget=4096, seed=3, options=(("hot_k", 32),))
table = EmbeddingTable(cfg)
params = table.init(jax.random.key(0))
bufs = table.make_buffers()
rng = np.random.default_rng(0)
ids = jnp.asarray(rng.integers(0, 300, (64,), np.int32))
want = np.asarray(table.embed(params, bufs, 0, ids))
with use_mesh(mesh):
    assert isinstance(bke.resolve_backend(cfg, params),
                      bke.ShardedBackend)
    got = np.asarray(table.embed(params, bufs, 0, ids))
np.testing.assert_array_equal(got, want)
print("freq sharded OK")
"""
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "freq sharded OK" in r.stdout


def test_freq_trains_on_synthetic_ctr_smoke():
    """End-to-end: the freq scheme drops into the paper's DLRM smoke config
    (registry-driven embedding_of_kind) and a few adagrad steps move the
    loss — zero edits to dispatch code."""
    from repro.configs.lma_dlrm_criteo import make_smoke
    from repro.data.synthetic_ctr import CTRGenerator, CTRSpec
    from repro.models import recsys
    from repro.optim import optimizers as opt_lib

    cfg = make_smoke(embedding_kind="freq")
    assert cfg.embedding.kind == "freq"
    gen = CTRGenerator(CTRSpec(n_fields=cfg.n_fields, n_dense=cfg.n_dense,
                               vocab_sizes=cfg.embedding.vocab_sizes, seed=0))
    params = recsys.init(jax.random.key(0), cfg)
    opt = opt_lib.adagrad(1e-2)
    state = opt.init(params)

    @jax.jit
    def step(params, state, batch):
        (loss, _), grads = jax.value_and_grad(
            lambda p: recsys.loss_fn(p, cfg, batch, {}), has_aux=True)(params)
        updates, state = opt.update(grads, state, params)
        return opt_lib.apply_updates(params, updates), state, loss

    losses = []
    for i in range(8):
        batch = {k: jnp.asarray(v) for k, v in gen.batch(64, i).items()}
        params, state, loss = step(params, state, batch)
        losses.append(float(loss))
    assert np.isfinite(losses).all()
    assert min(losses[-3:]) < losses[0], losses
