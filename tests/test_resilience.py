"""Self-healing training: injector, step guard, integrity, exchange fallback.

Every resilience path is driven by the deterministic fault injector
(``repro.resilience.faults``), so outcomes are exact: a skipped step leaves
state bit-identical, a rolled-back run converges to the clean run's bits,
quarantined pool chunks zero out and the model keeps training.
"""
from __future__ import annotations

import os
import signal as signal_mod
import subprocess
import sys
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.dist import exchange as exl
from repro.optim import optimizers as opt_lib
from repro.optim import sparse as sparse_lib
from repro.resilience import faults as flt
from repro.resilience import guard as guard_lib
from repro.resilience import integrity as integ
from repro.resilience.exchange_guard import ExchangeGuard
from repro.resilience.health import Health
from repro.train.trainer import Trainer, TrainerConfig


@pytest.fixture(autouse=True)
def _clean_global_state():
    yield
    flt.install(None)
    exl.reset_demotions()


def _problem(noise=0.0):
    """Noise-free by default: clean and faulted runs both converge to ~0,
    making the <= 1e-6 loss-parity assertion exact."""
    rng = np.random.default_rng(0)
    w_true = rng.normal(0, 1, (8, 1)).astype(np.float32)

    def batch_fn(step):
        r = np.random.default_rng(step)
        x = r.normal(0, 1, (32, 8)).astype(np.float32)
        y = x @ w_true + noise * r.normal(0, 1, (32, 1)).astype(np.float32)
        return {"x": jnp.asarray(x), "y": jnp.asarray(y)}

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"]
        loss = jnp.mean((pred - batch["y"]) ** 2)
        return loss, {"mse": loss}

    return loss_fn, batch_fn


def _trainer(total_steps, faults=None, ckpt_dir=None, **cfg_kw):
    loss_fn, batch_fn = _problem()
    cfg = TrainerConfig(total_steps=total_steps, log_every=0,
                        ckpt_dir=ckpt_dir, **cfg_kw)
    inj = flt.FaultInjector(faults) if faults else None
    return Trainer(cfg, loss_fn, {"w": jnp.zeros((8, 1), jnp.float32)},
                   opt_lib.adam(5e-2), batch_fn, faults=inj)


def _pool_problem(kind, m=32768, d=16, vocab=512):
    """Memory-pool regression problem exercising the sparse-grad path."""
    from repro.core.signatures import synthetic_dense_store
    from repro.embed import EmbeddingTable, get_scheme

    scheme = get_scheme(kind)
    table = EmbeddingTable(scheme.build_config((vocab,), d, m, seed=3))
    store = (synthetic_dense_store(vocab, 64, max_set=16, seed=2)
             if scheme.buffer_source == "signatures" else None)
    bufs = table.make_buffers(store)
    rng = np.random.default_rng(1)
    Y = rng.normal(size=(vocab, d)).astype(np.float32)

    def batch_fn(step):
        r = np.random.default_rng(step)
        ids = r.integers(0, vocab, (64,), np.int32)
        return {"ids": jnp.asarray(ids), "y": jnp.asarray(Y[ids])}

    def loss_fn(params, batch):
        e = table.embed(params["embedding"], bufs, 0, batch["ids"])
        loss = jnp.mean((e - batch["y"]) ** 2)
        return loss, {}

    params = {"embedding": table.init(jax.random.key(0))}
    return loss_fn, batch_fn, params


def _tree_bit_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------------------------ fault grammar

def test_fault_grammar():
    fs = flt.parse_faults("nan_grad@17, rot_row@40:8 ,slow_rank@55:0.5")
    assert [(f.kind, f.step, f.arg) for f in fs] == [
        ("nan_grad", 17, None), ("rot_row", 40, 8.0), ("slow_rank", 55, 0.5)]
    assert flt.parse_faults("") == []
    with pytest.raises(ValueError, match="unknown fault kind"):
        flt.parse_faults("bad_kind@3")
    with pytest.raises(ValueError, match="malformed"):
        flt.parse_faults("nan_grad")
    with pytest.raises(ValueError, match="malformed"):
        flt.parse_faults("nan_grad@x")


def test_grad_fault_fires_once():
    inj = flt.FaultInjector("inf_grad@2")
    assert inj.grad_fault(1) == 1.0
    assert inj.grad_fault(2) == float("inf")
    assert inj.grad_fault(2) == 1.0     # transient: consumed
    inj.reset()
    assert inj.grad_fault(2) == float("inf")


def test_from_env(monkeypatch):
    monkeypatch.setenv("REPRO_FAULTS", "nan_grad@5")
    inj = flt.from_env()
    assert inj is not None and inj.faults[0].kind == "nan_grad"
    assert flt.active_injector() is inj
    monkeypatch.setenv("REPRO_FAULTS", "")
    assert flt.from_env() is None


# ------------------------------------------------------------- guarded step

@pytest.mark.parametrize("fault", ["nan_grad", "inf_grad", "huge_grad"])
def test_skipped_step_is_bit_exact_noop(fault):
    """The acceptance-criterion core: a poisoned step leaves params,
    opt_state and every Adam moment bit-identical to the pre-step state."""
    t_clean = _trainer(total_steps=2)
    t_clean.fit(log=lambda *_: None)

    t_fault = _trainer(total_steps=3, faults=f"{fault}@2")
    out = t_fault.fit(log=lambda *_: None)
    assert out["step"] == 3
    assert out["skipped_steps"] == 1 and out["nonfinite_grads"] == 1
    # state after (clean 0, clean 1, skipped 2) == state after (clean 0, 1)
    _tree_bit_equal(t_clean.params, t_fault.params)
    _tree_bit_equal(t_clean.opt_state, t_fault.opt_state)


def test_skipped_step_sparse_pool_bit_exact():
    """Same bit-exactness through the SparseGrad path (lma striped: bucketed
    ``unique=False`` streams) — the donated pool and adagrad moments come
    back untouched from the skip branch."""
    loss_fn, batch_fn, params = _pool_problem("lma")
    opt = opt_lib.adagrad(0.1)

    def run(steps, faults=None):
        _, _, p = _pool_problem("lma")
        inj = flt.FaultInjector(faults) if faults else None
        t = Trainer(TrainerConfig(total_steps=steps, log_every=0),
                    loss_fn, p, opt, batch_fn, faults=inj)
        assert t.sparse_grads, "pool problem must exercise the sparse path"
        t.fit(log=lambda *_: None)
        return t

    t_clean = run(3)
    t_fault = run(4, faults="nan_grad@3")
    assert t_fault.health.skipped_steps == 1
    _tree_bit_equal(t_clean.params, t_fault.params)
    _tree_bit_equal(t_clean.opt_state, t_fault.opt_state)


def test_huge_grad_caught_by_magnitude_bound():
    """1e30-scaled gradients are *finite* — only the |g| <= max_abs_grad
    bound catches them before the optimizer squares them into inf."""
    t = _trainer(total_steps=3, faults="huge_grad@1")
    t.fit(log=lambda *_: None)
    assert t.health.skipped_steps == 1
    assert np.isfinite(np.asarray(t.params["w"])).all()


def test_recovery_to_loss_parity():
    """After the skip, training recovers: final loss within 1e-6 of the
    un-faulted run (noise-free problem; both converge to ~0)."""
    r_clean = _trainer(total_steps=150).fit(log=lambda *_: None)
    r_fault = _trainer(total_steps=150, faults="nan_grad@3").fit(
        log=lambda *_: None)
    assert r_fault["skipped_steps"] == 1
    assert abs(r_clean["loss"] - r_fault["loss"]) <= 1e-6


def test_skip_is_independent_of_poison_value():
    """NaN and inf poison at the same step must leave identical bits — the
    cond's skip branch never reads the poisoned update."""
    t_a = _trainer(total_steps=10, faults="nan_grad@4")
    t_b = _trainer(total_steps=10, faults="inf_grad@4")
    t_a.fit(log=lambda *_: None)
    t_b.fit(log=lambda *_: None)
    _tree_bit_equal(t_a.params, t_b.params)
    _tree_bit_equal(t_a.opt_state, t_b.opt_state)


def test_unguarded_step_applies_poison():
    """guard_step=False restores the fast path: the NaN lands in params
    (and the checkpoint manager then refuses to persist it)."""
    t = _trainer(total_steps=3, faults="nan_grad@1", guard_step=False)
    t.fit(log=lambda *_: None)
    assert t.health.skipped_steps == 0
    assert not np.isfinite(np.asarray(t.params["w"])).all()


def test_guard_env_gate(monkeypatch):
    monkeypatch.setenv("REPRO_GUARD_STEP", "0")
    assert not guard_lib.guard_enabled()
    t = _trainer(total_steps=1)
    assert t.guard is False
    monkeypatch.setenv("REPRO_GUARD_STEP", "1")
    assert guard_lib.guard_enabled()


# ------------------------------------------------------------------ rollback

def test_rollback_restores_and_recovers_bit_exact(tmp_path):
    """Two skips in a row roll back to the last checkpoint; the transient
    faults are consumed, the replayed steps apply cleanly, and the final
    state is bit-identical to a never-faulted run."""
    t_fault = _trainer(total_steps=10, faults="nan_grad@4,nan_grad@5",
                       ckpt_dir=str(tmp_path / "a"), ckpt_every=2,
                       max_consecutive_skips=2, rollback_backoff=0.01)
    out = t_fault.fit(log=lambda *_: None)
    assert out["rollbacks"] == 1 and out["retries"] >= 1
    assert out["skipped_steps"] == 2
    assert out["step"] == 10 and not out["preempted"]

    t_clean = _trainer(total_steps=10, ckpt_dir=str(tmp_path / "b"),
                       ckpt_every=2)
    t_clean.fit(log=lambda *_: None)
    _tree_bit_equal(t_clean.params, t_fault.params)
    _tree_bit_equal(t_clean.opt_state, t_fault.opt_state)


def test_rollback_gives_up_loudly():
    """Bounded: persistent non-finite steps end in RuntimeError, not an
    infinite rollback loop."""
    t = _trainer(total_steps=10, faults="nan_grad@1,nan_grad@2",
                 max_consecutive_skips=1, max_rollbacks=1,
                 rollback_backoff=0.0)
    with pytest.raises(RuntimeError, match="giving up"):
        t.fit(log=lambda *_: None)
    assert t.health.rollbacks == 2


def test_rollback_backoff_is_bounded():
    t = _trainer(total_steps=1, rollback_backoff=0.05,
                 rollback_backoff_max=0.2, max_rollbacks=100)
    delays = [min(t.cfg.rollback_backoff * (2 ** k), t.cfg.rollback_backoff_max)
              for k in range(10)]
    assert delays[0] == 0.05 and max(delays) == 0.2


# ------------------------------------------------- stragglers and preemption

def test_slow_rank_fault_counts_straggler():
    t = _trainer(total_steps=24, faults="slow_rank@20:0.3")
    t.fit(log=lambda *_: None)
    assert t.health.straggler_steps >= 1


def test_preempt_fault_and_unified_result(tmp_path):
    """The preempted exit path returns the SAME result keys as normal
    completion (the old dict silently dropped straggler_steps)."""
    t = _trainer(total_steps=50, faults="preempt@3",
                 ckpt_dir=str(tmp_path), ckpt_every=5)
    out = t.fit(log=lambda *_: None)
    assert out["preempted"] and out["step"] == 3
    normal = _trainer(total_steps=2).fit(log=lambda *_: None)
    assert set(out) == set(normal)
    for key in ("straggler_steps", "skipped_steps", "rollbacks",
                "quarantined_chunks", "exchange_demotions"):
        assert key in out


def test_second_sigint_restores_default_handler():
    t = _trainer(total_steps=1)
    orig_int = signal_mod.getsignal(signal_mod.SIGINT)
    orig_term = signal_mod.getsignal(signal_mod.SIGTERM)
    try:
        t.install_signal_handlers()
        handler = signal_mod.getsignal(signal_mod.SIGINT)
        assert handler not in (orig_int, signal_mod.SIG_DFL)
        handler(signal_mod.SIGINT, None)          # graceful: flag + keep going
        assert t._preempted
        assert signal_mod.getsignal(signal_mod.SIGINT) is handler
        handler(signal_mod.SIGINT, None)          # hung save: make us killable
        assert signal_mod.getsignal(signal_mod.SIGINT) is signal_mod.SIG_DFL
    finally:
        signal_mod.signal(signal_mod.SIGINT, orig_int)
        signal_mod.signal(signal_mod.SIGTERM, orig_term)


def test_try_resume_waits_for_inflight_async_save(tmp_path):
    """An async save still writing must not race the restore."""
    t = _trainer(total_steps=5, ckpt_dir=str(tmp_path))
    t.fit(log=lambda *_: None)
    t.step = 7
    real_write = t.mgr._write

    def slow_write(step, host, *a):
        time.sleep(0.3)
        real_write(step, host, *a)

    t.mgr._write = slow_write
    t.save(blocking=False)               # in flight for >= 0.3 s
    t2 = _trainer(total_steps=9, ckpt_dir=str(tmp_path))
    t2.mgr = t.mgr                       # same manager: the rollback path
    assert t2.try_resume()
    assert t2.step == 7                  # saw the in-flight save, not step 5


# ------------------------------------------------------------ pool integrity

def test_integrity_checksum_device_host_parity():
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=(40000,)).astype(np.float32))
    dev = np.asarray(integ.chunk_checksums(x))
    host = integ.np_chunk_checksums(np.asarray(x))
    np.testing.assert_array_equal(dev, host)


def test_integrity_sanitize_quarantines_only_bad_chunks():
    x = jnp.arange(3 * integ.CHUNK, dtype=jnp.float32)
    bad = x.at[integ.CHUNK + 5].set(jnp.inf).at[7].set(1e38)
    clean, n_bad = integ.sanitize(bad)
    assert int(n_bad) == 2
    c = np.asarray(clean)
    assert (c[:integ.CHUNK] == 0).all()                     # chunk 0 zeroed
    assert (c[integ.CHUNK:2 * integ.CHUNK] == 0).all()      # chunk 1 zeroed
    np.testing.assert_array_equal(c[2 * integ.CHUNK:],
                                  np.asarray(x[2 * integ.CHUNK:]))


def test_integrity_sanitize_clean_is_bitwise_noop():
    x = jnp.asarray(np.random.default_rng(1).normal(
        size=(2 * integ.CHUNK + 17,)).astype(np.float32))
    clean, n_bad = integ.sanitize(x)
    assert int(n_bad) == 0
    np.testing.assert_array_equal(np.asarray(clean), np.asarray(x))


def test_rot_row_detected_quarantined_run_completes():
    """Injected slab bit-rot: the poisoned steps are skipped by the guard,
    the ckpt-boundary integrity scan quarantines the rotten chunks, and the
    run completes with a finite pool."""
    loss_fn, batch_fn, params = _pool_problem("lma")
    t = Trainer(
        TrainerConfig(total_steps=10, log_every=0, ckpt_every=4,
                      max_consecutive_skips=50),   # heal via scan, not rollback
        loss_fn, params, opt_lib.adagrad(0.1), batch_fn,
        faults=flt.FaultInjector("rot_row@5:4"))
    out = t.fit(log=lambda *_: None)
    assert out["step"] == 10
    assert out["quarantined_chunks"] >= 1
    mem = np.asarray(t.params["embedding"]["memory"])
    assert np.isfinite(mem).all() and np.abs(mem).max() <= integ.MAX_ABS


def test_restore_sanitizes_pool(tmp_path):
    """A restored checkpoint that somehow carries corruption (verify=False
    path, legacy ckpt) is scanned on resume."""
    loss_fn, batch_fn, params = _pool_problem("hashed_row")
    cfg = TrainerConfig(total_steps=4, log_every=0, ckpt_dir=str(tmp_path),
                        ckpt_every=2)
    t = Trainer(cfg, loss_fn, params, opt_lib.adagrad(0.1), batch_fn)
    t.fit(log=lambda *_: None)
    # corrupt BOTH saved pool leaves (params and the adagrad accumulator)
    # *and* their recorded integrity, so restore's manifest verification
    # passes and only the trainer-side scan can catch it
    import json
    step_dir = os.path.join(str(tmp_path), "step_0000000004")
    p = os.path.join(step_dir, "arrays.npz")
    with np.load(p) as z:
        host = {k: z[k].copy() for k in z.files}
    keys = [k for k in host if k.endswith("memory")]
    assert len(keys) == 2          # params/.../memory + opt_state/.../memory
    for key in keys:
        host[key][3] = np.float32("nan")
    np.savez(p, **host)
    from repro.checkpoint.manager import _leaf_sha, _tree_digest
    man_path = os.path.join(step_dir, "manifest.json")
    with open(man_path) as f:
        man = json.load(f)
    man["checksum"] = _tree_digest(host)
    for key in keys:
        man["leaves"][key]["sha256"] = _leaf_sha(host[key])
        man["integrity"][key]["checksums"] = [
            int(c) for c in integ.np_chunk_checksums(host[key])]
    with open(man_path, "w") as f:
        json.dump(man, f)

    loss_fn2, batch_fn2, params2 = _pool_problem("hashed_row")
    t2 = Trainer(cfg, loss_fn2, params2, opt_lib.adagrad(0.1), batch_fn2)
    assert t2.try_resume()
    assert t2.health.quarantined_chunks >= 2
    assert np.isfinite(np.asarray(t2.params["embedding"]["memory"])).all()
    for leaf in jax.tree_util.tree_leaves(t2.opt_state):
        assert np.isfinite(np.asarray(leaf)).all()


def test_save_refuses_nonfinite_state(tmp_path):
    """With the guard off, poison reaches params — and the checkpoint
    manager must refuse to persist it."""
    from repro.checkpoint.manager import CheckpointManager
    t = _trainer(total_steps=3, faults="nan_grad@1", guard_step=False)
    t.fit(log=lambda *_: None)
    assert not np.isfinite(np.asarray(t.params["w"])).all()
    mgr = CheckpointManager(str(tmp_path))
    with pytest.raises(ValueError, match="non-finite"):
        mgr.save(3, {"params": t.params})
    assert mgr.latest_step() is None     # nothing was persisted
    mgr.save(3, {"params": t.params}, check_finite=False)  # debug escape
    assert mgr.latest_step() == 3


def test_ctr_smoke_survives_bit_rot_with_bounded_auc_dent():
    """The tentpole's graceful-degradation claim on the CTR smoke model:
    bit-rot mid-training is quarantined (zeroed LMA chunks) and the run
    finishes with a measured — bounded — AUC dent instead of crashing."""
    import dataclasses as dc

    from repro.configs._recsys_common import embedding_of_kind
    from repro.configs.lma_dlrm_criteo import make_model
    from repro.core.embedding import make_buffers as core_make_buffers
    from repro.core.signatures import build_signature_store, densify_store
    from repro.data.metrics import StreamingEval
    from repro.data.synthetic_ctr import CTRGenerator, CTRSpec
    from repro.models import recsys

    # expansion=1.0 -> m=32768 = 4 integrity chunks, so quarantining the one
    # rotten chunk zeroes 1/4 of the pool (expansion=8 would leave a
    # single-chunk pool, where quarantine == losing everything)
    vocabs = tuple(150 + (i * 37) % 250 for i in range(8))
    cfg = make_model(embedding_kind="lma", expansion=1.0)
    emb = embedding_of_kind("lma", vocabs, 16, expansion=1.0, max_set=32)
    cfg = dc.replace(cfg, embedding=emb, n_dense=4, bot_mlp=(32, 16),
                     top_mlp=(64, 1))
    spec = CTRSpec(n_fields=8, n_dense=4, vocab_sizes=vocabs, n_clusters=8,
                   p_signal=0.85, seed=0)
    gen = CTRGenerator(spec)
    store = build_signature_store(gen.rows_for_signatures(6000), sum(vocabs),
                                  max_per_value=32)
    bufs = core_make_buffers(cfg.embedding, densify_store(store, 32))

    def batch_fn(step):
        return {k: jnp.asarray(v) for k, v in gen.batch(256, step).items()}

    def loss_fn(p, b):
        return recsys.loss_fn(p, cfg, b, bufs)

    def auc_of(params):
        ev = StreamingEval()
        fwd = jax.jit(lambda p, b: recsys.forward(p, cfg, b, bufs))
        for i in range(6):
            b = gen.batch(512, 100_000 + i)
            jb = {k: jnp.asarray(v) for k, v in b.items() if k != "label"}
            ev.add(b["label"], np.asarray(fwd(params, jb)))
        return ev.compute()["auc"]

    def run(faults=None):
        params = recsys.init(jax.random.key(0), cfg)
        t = Trainer(
            TrainerConfig(total_steps=100, log_every=0, ckpt_every=10,
                          max_consecutive_skips=50),
            loss_fn, params, opt_lib.adagrad(0.05), batch_fn,
            faults=flt.FaultInjector(faults) if faults else None)
        t.fit(log=lambda *_: None)
        return t

    t_clean = run()
    t_rot = run(faults="rot_row@55:1")   # 1 element -> exactly 1 bad chunk
    assert t_rot.health.quarantined_chunks >= 1
    auc_clean, auc_rot = auc_of(t_clean.params), auc_of(t_rot.params)
    dent = auc_clean - auc_rot
    print(f"[resilience] CTR smoke AUC clean {auc_clean:.4f} vs bit-rot "
          f"{auc_rot:.4f} (dent {dent:+.4f}, "
          f"{t_rot.health.quarantined_chunks} chunk(s) quarantined)")
    assert auc_rot > 0.60          # still far above chance
    assert dent < 0.10             # graceful, not catastrophic


# -------------------------------------------------------- exchange demotion

def fake_mesh(**axes):
    from types import SimpleNamespace
    return SimpleNamespace(shape=axes)


def test_demote_effective_and_reset():
    assert exl.effective("all_to_all") == "all_to_all"
    assert exl.demote("all_to_all", "test") == "ring"
    assert exl.effective("all_to_all") == "ring"
    assert exl.demote("ring", "test") == "psum"
    assert exl.effective("all_to_all") == "psum"
    assert exl.effective("psum") == "psum"
    with pytest.raises(ValueError):
        exl.demote("psum")
    with pytest.raises(KeyError):
        exl.demote("nope")
    exl.reset_demotions()
    assert exl.effective("all_to_all") == "all_to_all"


def test_resolver_honors_demotions():
    mesh = fake_mesh(data=2, model=4)
    # big batch, fused discount off: a chunked strategy wins the cost model
    picked = exl.resolve_exchange(mesh, B=4096, d=32, fused=False)
    assert picked.name in ("ring", "all_to_all")
    exl.demote("all_to_all", "test")
    assert exl.resolve_exchange(mesh, B=4096, d=32, fused=False).name in (
        "ring", "psum")
    exl.demote("ring", "test")
    assert exl.resolve_exchange(mesh, B=4096, d=32, fused=False).name == "psum"
    # the update exchange follows: demoted all_to_all -> psum oracle
    assert exl.resolve_update_exchange(mesh) is exl.PSUM


def test_forced_strategy_maps_through_demotion():
    mesh = fake_mesh(data=2, model=4)
    old = exl.FORCED
    try:
        exl.FORCED = "all_to_all"
        assert exl.resolve_exchange(mesh, B=4096, d=32).name == "all_to_all"
        exl.demote("all_to_all", "test")
        assert exl.resolve_exchange(mesh, B=4096, d=32).name == "ring"
    finally:
        exl.FORCED = old


def test_exchange_guard_demotes_after_retry():
    oracle = np.arange(12, dtype=np.float32).reshape(4, 3)
    calls = []

    def probe(name):
        calls.append(name)
        if name == "all_to_all":
            return np.zeros_like(oracle)     # dropped chunk: wrong bits
        return oracle                        # psum oracle and ring agree

    h = Health()
    g = ExchangeGuard(probe, health=h, log=lambda *_: None)
    assert g.validate() == "ring"
    assert "all_to_all" in exl.DEMOTED and "ring" not in exl.DEMOTED
    assert h.exchange_demotions == 1 and h.retries == 1
    assert calls.count("all_to_all") == 2    # failed, retried, then demoted


def test_exchange_guard_transient_failure_recovers():
    oracle = np.ones((4,), np.float32)
    state = {"n": 0}

    def probe(name):
        if name == "all_to_all":
            state["n"] += 1
            if state["n"] == 1:
                return np.zeros_like(oracle)  # one transient glitch
        return oracle

    h = Health()
    g = ExchangeGuard(probe, health=h, log=lambda *_: None)
    assert g.validate() == "all_to_all"
    assert not exl.DEMOTED and h.exchange_demotions == 0 and h.retries == 1


def test_exchange_guard_finite_check_without_oracle():
    def probe(name):
        if name == "all_to_all":
            return np.asarray([1.0, np.nan], np.float32)
        return np.asarray([1.0, 2.0], np.float32)

    g = ExchangeGuard(probe, log=lambda *_: None, use_oracle=False)
    assert g.validate() == "ring"
    assert exl.DEMOTED["all_to_all"].startswith("non-finite")


def test_exchange_guard_all_chunked_fail():
    def probe(name):
        if name == "psum":
            return np.ones((4,), np.float32)
        return np.zeros((4,), np.float32)

    h = Health()
    g = ExchangeGuard(probe, health=h, log=lambda *_: None)
    assert g.validate() == "psum"
    assert set(exl.DEMOTED) == {"all_to_all", "ring"}
    assert h.exchange_demotions == 2


def test_faulty_exchange_wrapper_mangles_lookup_name_preserved():
    inj = flt.FaultInjector("drop_chunk@0")
    wrapped = flt.FaultyExchange(exl.ALL_TO_ALL, inj)
    assert wrapped.name == "all_to_all" and wrapped.partial_updates
    out = wrapped._mangle(jnp.ones((8, 4)), n_model=4)
    np.testing.assert_array_equal(np.asarray(out[:2]), 0.0)
    np.testing.assert_array_equal(np.asarray(out[2:]), 1.0)
    # corrupt variant NaNs the chunk instead
    inj2 = flt.FaultInjector("corrupt_chunk@0")
    out2 = flt.FaultyExchange(exl.RING, inj2)._mangle(jnp.ones((8, 4)), 4)
    assert np.isnan(np.asarray(out2[:2])).all()


def test_wrap_exchange_only_when_armed_and_not_psum():
    assert flt.wrap_exchange(exl.RING) is exl.RING        # no injector
    flt.install(flt.FaultInjector("drop_chunk@0"))
    assert isinstance(flt.wrap_exchange(exl.RING), flt.FaultyExchange)
    assert flt.wrap_exchange(exl.PSUM) is exl.PSUM        # oracle exempt
    flt.install(flt.FaultInjector("nan_grad@0"))          # no chunk fault
    assert flt.wrap_exchange(exl.RING) is exl.RING


# --------------------------------------- end-to-end demotion on a real mesh

_DEMOTION_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8")
import numpy as np
import jax, jax.numpy as jnp
from repro.core.allocation import alloc_hashed_elem
from repro.core.memory import init_memory, lookup
from repro.dist import exchange as exl
from repro.dist.context import use_mesh
from repro.dist.sharded_memory import sharded_hashed_lookup
from repro.resilience import faults as flt
from repro.resilience.exchange_guard import ExchangeGuard
from repro.resilience.health import Health

m, d, B = 1 << 15, 16, 256
mem = init_memory(jax.random.key(0), m, "normal", 0.1)
gids = jnp.asarray(np.random.default_rng(1).integers(0, 4096, (B,), np.int32))
mesh = jax.make_mesh((2, 4), ("data", "model"))

# the injected chunk drop reaches every chunked strategy via _resolve's wrap
flt.install(flt.FaultInjector("drop_chunk@0"))

def probe(name):
    with use_mesh(mesh):
        out = sharded_hashed_lookup(mem, gids, d, m, 7, mesh, ("data",),
                                    exchange=name)
    return np.asarray(out)

h = Health()
guard = ExchangeGuard(probe, health=h, log=lambda s: print(s))
final = guard.validate()
assert final == "psum", final
assert set(exl.DEMOTED) == {"all_to_all", "ring"}, exl.DEMOTED
assert h.exchange_demotions == 2 and h.retries == 2, h

# after demotion the auto-resolver lands on psum, whose lookup is
# bit-identical to the replicated oracle even with the injector still armed
with use_mesh(mesh):
    auto = sharded_hashed_lookup(mem, gids, d, m, 7, mesh, ("data",))
oracle = lookup(mem, alloc_hashed_elem(gids, d, m, 7))
np.testing.assert_array_equal(np.asarray(auto), np.asarray(oracle))
print("OK demotion ladder -> psum, lookups bit-identical")
"""


@pytest.mark.slow
def test_chunk_drop_demotes_to_psum_bit_identical(tmp_path):
    """Acceptance criterion (d): injected all_to_all chunk drop demotes to
    ring then psum, and the surviving lookups are bit-identical to the
    replicated oracle."""
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("REPRO_DIST_EXCHANGE", None)
    env.pop("REPRO_FAULTS", None)
    r = subprocess.run([sys.executable, "-c", _DEMOTION_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=1800)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "OK demotion ladder" in r.stdout
