"""CheckpointManager: atomicity, integrity, retention, async, elasticity."""
from __future__ import annotations

import json
import os

import numpy as np
import pytest

try:  # optional dev dep: only the property-based tests need it
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager, _flatten, _unflatten


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.normal(0, 1, (4, 3)).astype(np.float32)),
                   "b": jnp.asarray(rng.normal(0, 1, 3).astype(np.float32))},
        "opt": ({"m": jnp.zeros((4, 3))}, {"v": jnp.ones((4, 3))}),
        "step": jnp.asarray(7, jnp.int32),
    }


def _assert_tree_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    tree = _tree()
    mgr.save(10, tree)
    step, restored = mgr.restore()
    assert step == 10
    _assert_tree_equal(tree, restored)


def test_latest_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    assert mgr.latest_step() == 4
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(dirs) == 2          # keep=2
    step, restored = mgr.restore()
    _assert_tree_equal(_tree(4), restored)


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(5, _tree(5), blocking=False)
    mgr.wait()
    step, restored = mgr.restore()
    assert step == 5
    _assert_tree_equal(_tree(5), restored)


def test_checksum_detects_corruption(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _tree())
    man_path = os.path.join(tmp_path, "step_0000000001", "manifest.json")
    with open(man_path) as f:
        man = json.load(f)
    man["checksum"] = "0" * 64
    with open(man_path, "w") as f:
        json.dump(man, f)
    with pytest.raises(IOError):
        mgr.restore()
    # verify=False bypass still loads
    step, _ = mgr.restore(verify=False)
    assert step == 1


def test_no_tmp_dirs_left_behind(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _tree())
    assert not [d for d in os.listdir(tmp_path) if d.startswith(".tmp")]


def test_latest_marker_fallback(tmp_path):
    """A stale LATEST pointing at a deleted dir falls back to newest valid."""
    mgr = CheckpointManager(str(tmp_path), keep=5)
    mgr.save(1, _tree(1))
    mgr.save(2, _tree(2))
    import shutil
    shutil.rmtree(os.path.join(tmp_path, "step_0000000002"))
    assert mgr.latest_step() == 1
    step, restored = mgr.restore()
    assert step == 1


def test_idempotent_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _tree(1))
    mgr.save(1, _tree(99))      # ignored: step already durable
    _, restored = mgr.restore()
    _assert_tree_equal(_tree(1), restored)


def test_elastic_restore_device_put(tmp_path):
    """Restore with a shardings callable (the elastic re-mesh path)."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(3, _tree())
    mesh = jax.make_mesh((1,), ("x",))
    sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    step, restored = mgr.restore(shardings=lambda path: sh)
    leaf = jax.tree_util.tree_leaves(restored)[0]
    assert leaf.sharding == sh


if HAVE_HYPOTHESIS:
    leaf_st = st.one_of(
        st.integers(-5, 5).map(lambda i: np.asarray(i, np.int32)),
        st.lists(st.floats(-1, 1, width=32), min_size=1, max_size=4)
          .map(lambda l: np.asarray(l, np.float32)),
    )
    tree_st = st.recursive(
        leaf_st,
        lambda children: st.one_of(
            st.dictionaries(st.sampled_from(list("abcd")), children,
                            min_size=1, max_size=3),
            st.tuples(children, children),
        ),
        max_leaves=8,
    )

    @settings(max_examples=30, deadline=None)
    @given(tree=tree_st)
    def test_property_flatten_unflatten_roundtrip(tree):
        flat = _flatten(tree)
        rebuilt = _unflatten(flat)
        la, lb = (jax.tree_util.tree_leaves(tree),
                  jax.tree_util.tree_leaves(rebuilt))
        assert len(la) == len(lb)
        for x, y in zip(la, lb):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
else:
    def test_property_flatten_unflatten_roundtrip():
        pytest.importorskip("hypothesis")


# -------------------------------------------------- self-healing restore

def _pool_tree(seed=0, m=3 * 8192):
    """A tree with an integrity-covered memory-pool leaf (> 1 chunk)."""
    rng = np.random.default_rng(seed)
    return {
        "params": {"memory": jnp.asarray(
            rng.normal(0, 0.1, (m,)).astype(np.float32)),
            "w": jnp.asarray(rng.normal(0, 1, (4, 3)).astype(np.float32))},
        "step": jnp.asarray(seed, jnp.int32),
    }


def test_restore_falls_back_on_truncated_latest(tmp_path):
    """A torn/truncated arrays.npz in the latest checkpoint is not fatal:
    restore walks back to the previous retained step."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _tree(1))
    mgr.save(2, _tree(2))
    npz = os.path.join(tmp_path, "step_0000000002", "arrays.npz")
    with open(npz, "r+b") as f:
        f.truncate(os.path.getsize(npz) // 2)
    step, restored = mgr.restore()
    assert step == 1
    _assert_tree_equal(_tree(1), restored)
    assert mgr.last_restore_report["fell_back_from"] == 2

    # with the only checkpoint torn, restore raises (listing what it tried)
    mgr2 = CheckpointManager(str(tmp_path / "solo"), keep=3)
    mgr2.save(7, _tree(7))
    npz = os.path.join(tmp_path, "solo", "step_0000000007", "arrays.npz")
    with open(npz, "r+b") as f:
        f.truncate(10)
    with pytest.raises(IOError, match="no restorable checkpoint"):
        mgr2.restore()


def test_explicit_step_never_falls_back(tmp_path):
    """restore(step=N) means those exact bytes: corruption raises even when
    older healthy steps exist."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _tree(1))
    mgr.save(2, _tree(2))
    npz = os.path.join(tmp_path, "step_0000000002", "arrays.npz")
    with open(npz, "r+b") as f:
        f.truncate(10)
    with pytest.raises(Exception):
        mgr.restore(step=2)
    step, _ = mgr.restore(step=1)      # older one still explicitly loadable
    assert step == 1


def test_chunk_repair_quarantines_pool_corruption(tmp_path):
    """Bit-flips inside an integrity-covered pool leaf are repaired in place
    (mismatched chunks zeroed) instead of discarding the checkpoint."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    tree = _pool_tree(3)
    mgr.save(3, tree)
    npz = os.path.join(tmp_path, "step_0000000003", "arrays.npz")
    with np.load(npz) as z:
        host = {k: z[k].copy() for k in z.files}
    host["params/memory"][8192 + 5] += 1.0     # rot inside chunk 1
    np.savez(npz, **host)
    step, restored = mgr.restore()
    assert step == 3
    mem = np.asarray(restored["params"]["memory"])
    want = np.asarray(tree["params"]["memory"])
    np.testing.assert_array_equal(mem[:8192], want[:8192])         # chunk 0
    assert (mem[8192:2 * 8192] == 0).all()                         # quarantined
    np.testing.assert_array_equal(mem[2 * 8192:], want[2 * 8192:])  # chunk 2
    assert mgr.last_restore_report == {
        "quarantined_chunks": 1, "repaired_leaves": ["params/memory"],
        "fell_back_from": None, "torn_writes": 0, "chain_len": 0}


def test_non_pool_corruption_falls_back(tmp_path):
    """Corruption in a leaf with no chunk integrity (a dense weight) cannot
    be repaired -> fall back to the previous step."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _pool_tree(1))
    mgr.save(2, _pool_tree(2))
    npz = os.path.join(tmp_path, "step_0000000002", "arrays.npz")
    with np.load(npz) as z:
        host = {k: z[k].copy() for k in z.files}
    host["params/w"][0, 0] += 1.0
    np.savez(npz, **host)
    step, restored = mgr.restore()
    assert step == 1
    assert mgr.last_restore_report["fell_back_from"] == 2


def test_save_refuses_nonfinite(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    tree = _tree()
    tree["params"]["w"] = tree["params"]["w"].at[0, 0].set(jnp.nan)
    with pytest.raises(ValueError, match="refusing to persist non-finite"):
        mgr.save(1, tree)
    assert mgr.latest_step() is None
    mgr.save(1, tree, check_finite=False)      # explicit debug override
    assert mgr.latest_step() == 1


def test_injected_read_failure_falls_back(tmp_path):
    """A read_fail fault makes the next host read raise -> restore falls
    back to the previous retained step."""
    from repro.resilience import faults as flt
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _tree(1))
    mgr.save(2, _tree(2))
    flt.install(flt.FaultInjector("read_fail@0"))
    try:
        step, restored = mgr.restore()
        assert step == 1
        assert mgr.last_restore_report["fell_back_from"] == 2
    finally:
        flt.install(None)
