"""Architecture configs: the assigned specs are encoded exactly, and the
derived parameter counts land on the published model sizes."""
from __future__ import annotations

import numpy as np
import pytest

from repro.configs.base import get_config, list_archs
from repro.models.transformer import param_count

ASSIGNED = {
    "stablelm-3b", "qwen1.5-32b", "tinyllama-1.1b", "deepseek-v3-671b",
    "llama4-scout-17b-a16e", "gat-cora", "din", "dlrm-rm2", "xdeepfm",
    "dcn-v2",
}


def test_all_assigned_archs_registered():
    assert ASSIGNED <= set(list_archs())
    # plus the paper's own runnable configs
    assert {"lma-dlrm-criteo", "lma-dlrm-avazu"} <= set(list_archs())


@pytest.mark.parametrize("arch_id", sorted(ASSIGNED))
def test_every_arch_has_smoke_and_shapes(arch_id):
    cfg = get_config(arch_id)
    assert callable(cfg.make_model) and callable(cfg.make_smoke)
    assert len(cfg.shapes) == (4 if cfg.family != "gnn" else 4)
    smoke = cfg.make_smoke()
    assert smoke is not None


LM_SPECS = {
    # arch: (L, d_model, H, KV, d_ff, vocab)
    "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
    "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
    "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
    "deepseek-v3-671b": (61, 7168, 128, 128, 2048, 129280),
    "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
}


@pytest.mark.parametrize("arch_id", sorted(LM_SPECS))
def test_lm_config_matches_assignment(arch_id):
    L, d, H, KV, dff, V = LM_SPECS[arch_id]
    cfg = get_config(arch_id).make_model("train_4k")
    assert cfg.n_layers == L
    assert cfg.d_model == d
    assert cfg.n_heads == H
    assert cfg.n_kv_heads == KV
    assert cfg.vocab_size == V
    if cfg.moe is None:
        assert cfg.d_ff == dff
    else:
        assert cfg.moe.d_ff == dff


def test_qwen_has_qkv_bias():
    assert get_config("qwen1.5-32b").make_model().qkv_bias is True


def test_deepseek_moe_shape():
    cfg = get_config("deepseek-v3-671b").make_model()
    assert cfg.attention == "mla"
    assert cfg.moe.n_experts == 256 and cfg.moe.top_k == 8
    assert cfg.moe.n_shared_experts == 1
    assert cfg.moe.router == "sigmoid"


def test_llama4_moe_shape():
    cfg = get_config("llama4-scout-17b-a16e").make_model()
    assert cfg.moe.n_experts == 16 and cfg.moe.top_k == 1


PARAM_BANDS = {
    # arch: (total_lo, total_hi, active_lo, active_hi)
    "tinyllama-1.1b": (0.9e9, 1.3e9, None, None),
    "stablelm-3b": (2.3e9, 3.3e9, None, None),
    "qwen1.5-32b": (27e9, 37e9, None, None),
    "deepseek-v3-671b": (600e9, 740e9, 30e9, 45e9),
    "llama4-scout-17b-a16e": (90e9, 120e9, 14e9, 20e9),
}


@pytest.mark.parametrize("arch_id", sorted(PARAM_BANDS))
def test_param_count_bands(arch_id):
    lo, hi, alo, ahi = PARAM_BANDS[arch_id]
    cfg = get_config(arch_id).make_model()
    total, active = param_count(cfg)
    assert lo < total < hi, f"{arch_id}: total {total/1e9:.1f}B"
    if alo is not None:
        assert alo < active < ahi, f"{arch_id}: active {active/1e9:.1f}B"


RECSYS_SPECS = {
    "dlrm-rm2": dict(model="dlrm", n_dense=13, n_fields=26, dim=64),
    "dcn-v2": dict(model="dcn", n_dense=13, n_fields=26, dim=16),
    "xdeepfm": dict(model="xdeepfm", n_dense=0, n_fields=39, dim=10),
    "din": dict(model="din", dim=18, hist_len=100),
}


@pytest.mark.parametrize("arch_id", sorted(RECSYS_SPECS))
def test_recsys_config_matches_assignment(arch_id):
    spec = RECSYS_SPECS[arch_id]
    cfg = get_config(arch_id).make_model("train_batch")
    assert cfg.model == spec["model"]
    assert cfg.embedding.dim == spec["dim"]
    if "n_fields" in spec:
        assert cfg.n_fields == spec["n_fields"]
    if "n_dense" in spec:
        assert cfg.n_dense == spec["n_dense"]
    if "hist_len" in spec:
        assert cfg.hist_len == spec["hist_len"]


def test_recsys_structures():
    dlrm = get_config("dlrm-rm2").make_model()
    assert dlrm.bot_mlp == (512, 256, 64) and dlrm.top_mlp == (512, 512, 256, 1)
    dcn = get_config("dcn-v2").make_model()
    assert dcn.n_cross_layers == 3 and dcn.deep_mlp == (1024, 1024, 512)
    xd = get_config("xdeepfm").make_model()
    assert xd.cin_layers == (200, 200, 200) and xd.deep_mlp == (400, 400)
    din = get_config("din").make_model()
    assert din.attn_mlp == (80, 40) and din.top_mlp == (200, 80)


def test_lma_budget_is_16x_compression():
    """Default expansion rate alpha=16 (paper section 7)."""
    cfg = get_config("dlrm-rm2").make_model()
    e = cfg.embedding
    assert e.kind == "lma"
    assert 15.0 < e.expansion_rate <= 16.5
    # budget divides every production mesh axis combination
    assert e.budget % 512 == 0


def test_gat_config():
    cfg = get_config("gat-cora").make_model("full_graph_sm")
    assert cfg.n_layers == 2 and cfg.d_hidden == 8 and cfg.n_heads == 8
    assert cfg.n_classes == 7 and cfg.d_in == 1433
