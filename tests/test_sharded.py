"""Multi-device sharding equivalence, run in a subprocess so this process's
device count stays 1 (the dry-run flag must never leak into other tests).

The subprocess forces 8 host devices, builds a (2, 4) ('data','model') mesh,
and checks that the sharded common-memory lookup is bit-identical to the
single-device oracle — forward AND gradients — both under the auto-resolved
exchange strategy and under the pinned psum oracle with the fused slab
kernel on/off (per-strategy coverage for every registered scheme lives in
tests/test_exchange.py).
"""
from __future__ import annotations

import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.allocation import LMAParams, alloc_lma, alloc_hashed_elem
from repro.core.memory import init_memory, lookup
from repro.core.signatures import synthetic_dense_store
from repro.dist.sharded_memory import sharded_hashed_lookup, sharded_lma_lookup
from repro.dist.context import use_mesh

assert len(jax.devices()) == 8, jax.devices()
mesh = jax.make_mesh((2, 4), ("data", "model"))

M_BUDGET = 4096            # divisible by model axis 4
N_VALUES = 512             # divisible by 4 (dense store rows shard over model)
D = 16

lma = LMAParams(d=D, m=M_BUDGET, n_h=2, max_set=16, seed=7)
store = synthetic_dense_store(N_VALUES, n_clusters=8, max_set=16, seed=1)
mem = init_memory(jax.random.key(0), M_BUDGET, "normal", 0.1)
rng = np.random.default_rng(0)
gids = jnp.asarray(rng.integers(0, N_VALUES, (64,), dtype=np.int32))

# ---- oracle (single device, no mesh)
loc = alloc_lma(lma, store, gids)
want = lookup(mem, loc)

def sharded(mem_):
    return sharded_lma_lookup(mem_, store.sets, store.lengths, gids, lma,
                              mesh, ("data",))

with use_mesh(mesh):
    got = sharded(mem)
np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
print("lma forward OK")

# ---- gradients: scatter-add onto the memory must match the oracle transpose
cot = jnp.asarray(rng.normal(0, 1, want.shape).astype(np.float32))

def loss_oracle(m):
    return jnp.vdot(lookup(m, loc), cot)

def loss_sharded(m):
    with use_mesh(mesh):
        return jnp.vdot(sharded(m), cot)

g_want = jax.grad(loss_oracle)(mem)
g_got = jax.grad(loss_sharded)(mem)
np.testing.assert_allclose(np.asarray(g_got), np.asarray(g_want),
                           rtol=1e-6, atol=1e-6)
print("lma grad OK")

# ---- hashed fallback path
for kind in ("hashed_elem", "hashed_row"):
    from repro.core.allocation import alloc_hashed_row
    alloc = alloc_hashed_elem if kind == "hashed_elem" else alloc_hashed_row
    loc_h = alloc(gids, D, M_BUDGET, 3)
    want_h = lookup(mem, loc_h)
    with use_mesh(mesh):
        got_h = sharded_hashed_lookup(mem, gids, D, M_BUDGET, 3, mesh,
                                      ("data",), kind=kind)
    np.testing.assert_array_equal(np.asarray(got_h), np.asarray(want_h))
    print(f"{kind} forward OK")

# ---- 2D input batch (leading axis dp-sharded, trailing replicated)
gids2 = jnp.asarray(rng.integers(0, N_VALUES, (16, 4), dtype=np.int32))
loc2 = alloc_lma(lma, store, gids2.reshape(-1))
want2 = lookup(mem, loc2).reshape(16, 4, D)
with use_mesh(mesh):
    got2 = sharded_lma_lookup(mem, store.sets, store.lengths, gids2, lma,
                              mesh, ("data",))
np.testing.assert_array_equal(np.asarray(got2), np.asarray(want2))
print("2d batch OK")

# ---- multi-pod mesh (pod axis joins the dp set)
mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
with use_mesh(mesh3):
    got3 = sharded_lma_lookup(mem, store.sets, store.lengths, gids, lma,
                              mesh3, ("pod", "data"))
np.testing.assert_array_equal(np.asarray(got3), np.asarray(want))
print("multi-pod OK")

# ---- fused per-shard gather under the pinned psum strategy: the psum body
# must actually run the fused slab kernel (slab fits VMEM budget), and
# flipping to the legacy split (alloc + local_gather_psum) path must not
# change a single bit — both equal the single-device oracle computed above.
# (The unpinned calls above exercise whatever resolve_exchange picks — ring
# at this shape — so oracle equality covers the auto path too.)
import repro.kernels.fused_embed.ops as feops
from repro.dist.sharded_memory import _fused_slab
assert feops.fused_enabled()
assert _fused_slab(mem[: M_BUDGET // 4])

def sharded_psum(mem_):
    return sharded_lma_lookup(mem_, store.sets, store.lengths, gids, lma,
                              mesh, ("data",), exchange="psum")

def loss_psum(m):
    with use_mesh(mesh):
        return jnp.vdot(sharded_psum(m), cot)

with use_mesh(mesh):
    got_fused = sharded_psum(mem)
g_fused = jax.grad(loss_psum)(mem)
feops.ENABLED = False
try:
    with use_mesh(mesh):
        got_split = sharded_psum(mem)
    g_split = jax.grad(loss_psum)(mem)
finally:
    feops.ENABLED = True
np.testing.assert_array_equal(np.asarray(got_fused), np.asarray(want))
np.testing.assert_array_equal(np.asarray(got_split), np.asarray(got_fused))
np.testing.assert_allclose(np.asarray(g_split), np.asarray(g_fused),
                           rtol=1e-6, atol=1e-6)
np.testing.assert_allclose(np.asarray(g_fused), np.asarray(g_got),
                           rtol=1e-6, atol=1e-6)
for kind in ("hashed_elem", "hashed_row"):
    alloc = alloc_hashed_elem if kind == "hashed_elem" else alloc_hashed_row
    want_h = lookup(mem, alloc(gids, D, M_BUDGET, 3))
    feops.ENABLED = False
    try:
        with use_mesh(mesh):
            split_h = sharded_hashed_lookup(mem, gids, D, M_BUDGET, 3, mesh,
                                            ("data",), kind=kind,
                                            exchange="psum")
    finally:
        feops.ENABLED = True
    with use_mesh(mesh):
        fused_h = sharded_hashed_lookup(mem, gids, D, M_BUDGET, 3, mesh,
                                        ("data",), kind=kind,
                                        exchange="psum")
    np.testing.assert_array_equal(np.asarray(fused_h), np.asarray(want_h))
    np.testing.assert_array_equal(np.asarray(fused_h), np.asarray(split_h))
print("fused-vs-split slab gather OK")

print("ALL_SHARDED_CHECKS_PASSED")
"""


FLASH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np
import jax
import jax.numpy as jnp

from repro.dist.flash_decode import sharded_flash_decode
from repro.nn.attention import blocked_attention, quantize_kv, dequantize_kv

assert len(jax.devices()) == 8
mesh = jax.make_mesh((2, 4), ("data", "model"))

B, L, KV, G, hd = 4, 64, 2, 3, 16
H = KV * G
rng = np.random.default_rng(0)
q = jnp.asarray(rng.normal(0, 1, (B, 1, H, hd)).astype(np.float32))
kc = jnp.asarray(rng.normal(0, 1, (B, L, KV, hd)).astype(np.float32))
vc = jnp.asarray(rng.normal(0, 1, (B, L, KV, hd)).astype(np.float32))
kn = jnp.asarray(rng.normal(0, 1, (B, 1, KV, hd)).astype(np.float32))
vn = jnp.asarray(rng.normal(0, 1, (B, 1, KV, hd)).astype(np.float32))
clen = jnp.asarray(37, jnp.int32)   # mid-cache write position
sm = 1.0 / np.sqrt(hd)

# oracle: single-device dynamic update + blocked attention
k_ref = jax.lax.dynamic_update_slice_in_dim(kc, kn, 37, axis=1)
v_ref = jax.lax.dynamic_update_slice_in_dim(vc, vn, 37, axis=1)
o_ref = blocked_attention(
    q, k_ref, v_ref, causal=False,
    q_positions=jnp.asarray([37], jnp.int32),
    kv_positions=jnp.arange(L, dtype=jnp.int32),
    kv_valid_len=clen + 1, block=16)

o, k2, v2 = sharded_flash_decode(q, kc, vc, kn, vn, clen, sm_scale=sm,
                                 mesh=mesh, dp_axes=("data",))
np.testing.assert_array_equal(np.asarray(k2), np.asarray(k_ref))
np.testing.assert_array_equal(np.asarray(v2), np.asarray(v_ref))
np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                           rtol=2e-5, atol=2e-5)
print("flash float OK")

# int8 path: quantize cache + new entries; compare against dequant oracle
kq, ks = quantize_kv(kc)
vq, vs = quantize_kv(vc)
knq, kns = quantize_kv(kn)
vnq, vns = quantize_kv(vn)
o_q, k3, v3, ks3, vs3 = sharded_flash_decode(
    q, kq, vq, knq, vnq, clen, sm_scale=sm, mesh=mesh, dp_axes=("data",),
    k_scale=ks, v_scale=vs, k_scale_new=kns, v_scale_new=vns)
k_deq = dequantize_kv(k3, ks3, jnp.float32)
o_deq_ref = blocked_attention(
    q, k_deq, dequantize_kv(v3, vs3, jnp.float32), causal=False,
    q_positions=jnp.asarray([37], jnp.int32),
    kv_positions=jnp.arange(L, dtype=jnp.int32),
    kv_valid_len=clen + 1, block=16)
np.testing.assert_allclose(np.asarray(o_q), np.asarray(o_deq_ref),
                           rtol=2e-4, atol=2e-4)
# and the quantized result tracks the float result at int8 tolerance
np.testing.assert_allclose(np.asarray(o_q), np.asarray(o_ref),
                           rtol=0.12, atol=0.12)
print("flash int8 OK")

# B=1: cache length spreads over ALL axes (idle dp joins 'model')
q1, k1, v1 = q[:1], kc[:1], vc[:1]
o1, *_ = sharded_flash_decode(q1, k1, v1, kn[:1], vn[:1], clen, sm_scale=sm,
                              mesh=mesh, dp_axes=("data",))
np.testing.assert_allclose(np.asarray(o1), np.asarray(o_ref[:1]),
                           rtol=2e-5, atol=2e-5)
print("flash B=1 full-mesh OK")

print("ALL_FLASH_CHECKS_PASSED")
"""


def _run_sub(script):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    return subprocess.run([sys.executable, "-c", script], capture_output=True,
                          text=True, env=env, timeout=600)


@pytest.mark.slow
def test_sharded_lookup_equivalence_8dev():
    r = _run_sub(SCRIPT)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    assert "ALL_SHARDED_CHECKS_PASSED" in r.stdout


@pytest.mark.slow
def test_sharded_flash_decode_equivalence_8dev():
    r = _run_sub(FLASH_SCRIPT)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    assert "ALL_FLASH_CHECKS_PASSED" in r.stdout
