"""int8 KV-cache quantization: correctness of the quant/dequant path and of
decode against a quantized cache (single-device; the sharded path is covered
by tests/test_sharded.py + the dry-run)."""
from __future__ import annotations

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.models import transformer
from repro.nn.attention import dequantize_kv, quantize_kv


def test_quantize_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 3.0, (4, 7, 2, 16)).astype(np.float32))
    q, s = quantize_kv(x)
    assert q.dtype == jnp.int8 and s.shape == (4, 7, 2)
    back = dequantize_kv(q, s, jnp.float32)
    # absmax int8: error <= scale/2 = absmax/254 per row
    absmax = np.abs(np.asarray(x)).max(axis=-1, keepdims=True)
    err = np.abs(np.asarray(back) - np.asarray(x))
    assert (err <= absmax / 254.0 + 1e-6).all()


def test_quantize_zero_rows_safe():
    x = jnp.zeros((2, 3, 1, 8), jnp.float32)
    q, s = quantize_kv(x)
    back = dequantize_kv(q, s, jnp.float32)
    np.testing.assert_allclose(np.asarray(back), 0.0)


@pytest.mark.parametrize("arch_id", ["tinyllama-1.1b", "deepseek-v3-671b"])
def test_int8_decode_close_to_float(arch_id):
    """decode_step with an int8 cache tracks the float-cache logits."""
    base = get_config(arch_id).make_smoke()
    cfg_f = dataclasses.replace(base, kv_cache_dtype=None)
    cfg_q = dataclasses.replace(base, kv_cache_dtype="int8")
    params = transformer.init(jax.random.key(0), cfg_f)
    rng = np.random.default_rng(0)
    B, S = 2, 10
    tokens = jnp.asarray(rng.integers(0, base.vocab_size, (B, S), dtype=np.int32))

    outs = {}
    for name, cfg in (("f", cfg_f), ("q", cfg_q)):
        logits_p, cache = transformer.prefill(params, cfg, tokens[:, :S - 1])
        # grow to S
        cache = jax.tree_util.tree_map(
            lambda x: jnp.pad(x, [(0, 0), (0, 0), (0, 1)]
                              + [(0, 0)] * (x.ndim - 3)), cache)
        logits, _ = transformer.decode_step(
            params, cfg, tokens[:, S - 1], cache, jnp.asarray(S - 1, jnp.int32))
        outs[name] = np.asarray(logits, np.float32)
    # values close at int8 precision; float top-1 survives into the int8
    # top-5 (exact argmax is not stable on a random-init model's near-uniform
    # logits — adjacent logits differ by less than the quantization noise)
    np.testing.assert_allclose(outs["q"], outs["f"], rtol=0.1, atol=0.15)
    top5_q = np.argsort(-outs["q"], axis=-1)[:, :5]
    top1_f = outs["f"].argmax(-1)
    for b in range(top1_f.shape[0]):
        assert top1_f[b] in top5_q[b], (b, top1_f[b], top5_q[b])


def test_int8_cache_shapes():
    cfg = dataclasses.replace(get_config("tinyllama-1.1b").make_smoke(),
                              kv_cache_dtype="int8")
    cache = transformer.init_cache(cfg, batch=2, max_len=8)
    g = cache["layers_0"]
    assert g["k"].dtype == jnp.int8 and g["v"].dtype == jnp.int8
    assert g["k_scale"].dtype == jnp.float32
    assert g["k_scale"].shape == g["k"].shape[:-1]
    # MLA layout
    cfg_m = dataclasses.replace(get_config("deepseek-v3-671b").make_smoke(),
                                kv_cache_dtype="int8")
    cache_m = transformer.init_cache(cfg_m, batch=2, max_len=8)
    for grp in cache_m.values():
        assert grp["ckv"].dtype == jnp.int8
        assert grp["ckv_scale"].shape == grp["ckv"].shape[:-1]
