"""repro.tier — the HBM-hot / host-cold tiered memory store.

The contract under test is *bit-exactness*: an over-budget pool trained
through the tiered store (async staged cold blocks, EMA re-tiering, host
writeback) must be indistinguishable — values AND optimizer moments — from
the same run with the pool fully resident.  The tests build up that claim:
remap identity -> store round-trip -> re-tier migration -> the public
embed path -> a 25-step Trainer run with re-tiering against the resident
oracle.
"""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.embed import EmbeddingTable, get_scheme
from repro.embed import backends as bke
from repro.embed.config import EmbeddingConfig
from repro.optim import optimizers as opt_lib
from repro.tier import (BLOCK_DEFAULT, TieredStore, TierController,
                        budget_slots, needs_tiering, pool_leaf_paths,
                        remap_locations, split_batch, tier_split)
from repro.train.trainer import Trainer, TrainerConfig


# ------------------------------------------------------------ budget helpers

def test_budget_slots_floors_to_blocks():
    # 1 MB / 4 B = 262144 slots, already block-aligned
    assert budget_slots(1.0, itemsize=4, block=512) == 262144
    # a budget that lands mid-block is floored, never rounded up
    assert budget_slots(0.001, itemsize=4, block=512) == 0
    assert budget_slots(0.01, itemsize=4, block=512) == 2560  # 2621 -> 5 blocks


def test_tier_split_rules():
    assert tier_split(4096, None) == (4096, 0)            # no budget: all hot
    assert tier_split(4096, 1000.0) == (4096, 0)          # pool fits
    hot, cold = tier_split(1 << 20, 1.0, itemsize=4)
    assert hot == 262144 and cold == (1 << 20) - 262144
    assert hot % BLOCK_DEFAULT == 0


def test_tier_split_budget_covers_leaves_and_staging():
    """The budget bounds the WHOLE device footprint: each of the n_leaves
    compact leaves gets budget/n_leaves slots, and the stage region is
    carved out of that before the hot slab."""
    # 1 MB / 4 B = 262144 slots; two leaves -> 131072 each; 16 stage blocks
    # (8192 slots) leave 122880 hot
    hot, cold = tier_split(1 << 20, 1.0, itemsize=4, n_leaves=2,
                           stage_blocks=16)
    assert hot == 131072 - 16 * BLOCK_DEFAULT
    assert hot + cold == 1 << 20 and hot % BLOCK_DEFAULT == 0
    # a pool whose full n_leaves footprint fits stays all-hot, no staging
    assert tier_split(4096, 1.0, n_leaves=2, stage_blocks=16) == (4096, 0)
    # staging can exhaust the per-leaf budget: hot collapses to 0, loudly
    # checkable by the caller (the launcher refuses to run that config)
    assert tier_split(1 << 20, 1.0, itemsize=4, n_leaves=2,
                      stage_blocks=10_000)[0] == 0


def test_needs_tiering():
    assert not needs_tiering(4096, budget_mb=1000.0)
    assert needs_tiering(1 << 20, budget_mb=1.0)
    assert not needs_tiering(1 << 20, budget_mb=None)     # env unset: untiered
    # with the moment mirrors counted, half the budget per leaf
    assert needs_tiering(200_000, budget_mb=1.0, n_leaves=2)
    assert not needs_tiering(200_000, budget_mb=1.0, n_leaves=1)


# ---------------------------------------------------------- remap identity

def test_remap_locations_bit_identity():
    """take(compact, remap(loc)) == take(full, loc) for every location whose
    block is hot or staged — the invariant every tiered lookup rests on."""
    rng = np.random.default_rng(0)
    block, n_blocks = 64, 32
    m = block * n_blocks
    full = rng.normal(size=m).astype(np.float32)
    hot_ids = np.sort(rng.choice(n_blocks, 10, replace=False)).astype(np.int32)
    rest = np.setdiff1d(np.arange(n_blocks), hot_ids)
    staged = np.sort(rng.choice(rest, 6, replace=False)).astype(np.int32)
    # stage region padded with the n_blocks sentinel, like the store emits
    stage_ids = np.concatenate([staged, np.full(2, n_blocks, np.int32)])
    compact = np.concatenate([
        full.reshape(n_blocks, block)[hot_ids].reshape(-1),
        full.reshape(n_blocks, block)[staged].reshape(-1),
        np.zeros(2 * block, np.float32)])
    covered = np.concatenate([hot_ids, staged])
    loc = (rng.choice(covered, (37, 5)) * block
           + rng.integers(0, block, (37, 5))).astype(np.int32)
    got = jnp.take(jnp.asarray(compact),
                   remap_locations(jnp.asarray(loc), jnp.asarray(hot_ids),
                                   jnp.asarray(stage_ids), block))
    np.testing.assert_array_equal(np.asarray(got), full[loc])


def test_remap_locations_empty_tiers():
    loc = jnp.arange(8, dtype=jnp.int32)
    # all-hot pool (no stage): identity when hot_ids = arange
    got = remap_locations(loc, jnp.arange(4, dtype=jnp.int32),
                          jnp.full((1,), 4, jnp.int32), 2)
    np.testing.assert_array_equal(np.asarray(got), np.arange(8))


# ------------------------------------------------------------ store protocol

def _store(m=2048, block=128, hot_slots=512, seed=0, **kw):
    rng = np.random.default_rng(seed)
    mem = rng.normal(size=m).astype(np.float32)
    # full-cold staging, passed EXPLICITLY: the small-pool testing posture
    # (a defaulted stage capacity warns — it erases the HBM savings)
    kw.setdefault("stage_blocks", (m - hot_slots) // block)
    return mem, TieredStore(mem, hot_slots, block=block, **kw)


def test_defaulted_stage_capacity_warns():
    rng = np.random.default_rng(0)
    mem = rng.normal(size=2048).astype(np.float32)
    with pytest.warns(UserWarning, match="saves no HBM"):
        TieredStore(mem, 512, block=128)
    # explicit capacity (or an all-hot store) stays quiet
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        TieredStore(mem, 512, block=128, stage_blocks=4)
        TieredStore(mem, 2048, block=128)


def test_stage_install_writeback_round_trip():
    mem, st = _store()
    tree = {"memory": st.initial_compact()}
    # the full pool reconstructs the original bits before any step
    np.testing.assert_array_equal(st.full_pool(tree["memory"]), mem)
    blocks = np.array([0, 5, 9, 13])            # mix of hot (0..3) and cold
    st.stage(blocks)
    tree = st.install(tree)
    assert tree["memory"].shape == (st.compact_slots,)
    np.testing.assert_array_equal(st.full_pool(tree["memory"]), mem)
    # a training step bumps hot row 7 and a staged cold row
    upd = np.asarray(tree["memory"]).copy()
    upd[7] += 1.0
    upd[st.hot_slots + 3] += 2.0                # block 9's 4th slot... row 3
    tree = {"memory": jnp.asarray(upd)}
    st.writeback(tree)
    full = st.full_pool(tree["memory"])
    assert full[7] == mem[7] + 1.0
    # staged ids sorted -> [5, 9, 13]; slot 3 of the stage region is in
    # block 5 (stage row 0 covers slots 0..127)
    assert full[5 * 128 + 3] == mem[5 * 128 + 3] + 2.0


def test_stage_overflow_raises():
    _, st = _store(stage_blocks=2)
    with pytest.raises(ValueError, match="stage capacity"):
        st.stage(np.array([5, 7, 9]))           # 3 cold blocks, capacity 2


def test_register_leaf_rejects_nonuniform():
    _, st = _store()
    with pytest.raises(ValueError, match="uniform"):
        st.register_leaf("opt", jnp.arange(st.compact_slots, dtype=jnp.float32))


def test_retier_migrates_bits_and_moments():
    mem, st = _store(m=2048, block=128, hot_slots=512)
    acc0 = 0.1
    tree = {"memory": st.initial_compact(),
            "opt:acc": jnp.full(st.compact_slots, acc0, jnp.float32)}
    st.writeback(tree)                          # registers the moment leaf
    # make blocks 12..15 the hottest; incumbents 0..3 never observed
    st.observe(np.array([12, 13, 14, 15]), np.array([100, 90, 80, 70]))
    tree, info = st.retier(tree)
    assert info == {"promoted": 4, "demoted": 4}
    assert st.stats["promoted"] == 4
    np.testing.assert_array_equal(st.hot_ids, [12, 13, 14, 15])
    # migration is bit-exact for both leaves: the full pools are unchanged
    np.testing.assert_array_equal(st.full_pool(tree["memory"]), mem)
    np.testing.assert_array_equal(st.full_pool(tree["opt:acc"], "opt:acc"),
                                  np.full(2048, acc0, np.float32))
    # the new hot slab holds blocks 12..15's rows verbatim
    np.testing.assert_array_equal(
        np.asarray(tree["memory"][: st.hot_slots]), mem[12 * 128: 16 * 128])


def test_retier_hysteresis_and_max_swaps():
    _, st = _store(m=2048, block=128, hot_slots=512)
    tree = {"memory": st.initial_compact()}
    st.observe(np.arange(16), np.linspace(10, 12, 16))   # mild gradient
    # a 2x hysteresis bar: no challenger beats an incumbent by 2x
    tree, info = st.retier(tree, hysteresis=2.0)
    assert info == {"promoted": 0, "demoted": 0}
    np.testing.assert_array_equal(st.hot_ids, np.arange(4))
    # without the bar the top-4 swap in, capped at 1 swap
    tree, info = st.retier(tree, max_swaps=1, hysteresis=1.0)
    assert info == {"promoted": 1, "demoted": 1}


def test_sanitize_cold_quarantines_only_cold():
    mem, st = _store(m=2048, block=128, hot_slots=512)
    st._host["memory"][10, 5] = np.nan          # cold block: quarantined
    st._host["memory"][1, 5] = np.nan           # hot block: device-owned,
    n = st.sanitize_cold()                      # the in-run scan covers it
    assert n >= 1 and st.stats["quarantined_cold_chunks"] == n
    assert not np.isnan(st._host["memory"][10]).any()
    assert np.isnan(st._host["memory"][1, 5])


def test_counts_seed_hot_set():
    rng = np.random.default_rng(3)
    mem = rng.normal(size=2048).astype(np.float32)
    counts = np.zeros(16)
    counts[[3, 8, 11, 14]] = [50, 40, 30, 20]
    st = TieredStore(mem, 512, block=128, stage_blocks=12, counts=counts)
    np.testing.assert_array_equal(st.hot_ids, [3, 8, 11, 14])


# -------------------------------------------------- public embed path

def _embed_cfg():
    return EmbeddingConfig(kind="hashed_elem", vocab_sizes=(1000, 500),
                           dim=16, budget=4096)


def test_tiered_embed_fields_bit_exact():
    """The public EmbeddingTable path: compact pool + remap buffers in the
    embedding buffers -> bit-identical to the resident lookup."""
    cfg = _embed_cfg()
    table = EmbeddingTable(cfg)
    scheme = get_scheme(cfg.kind)
    bufs = table.make_buffers()
    params = table.init(jax.random.key(1))
    rng = np.random.default_rng(2)
    ids = jnp.asarray(np.stack([rng.integers(0, 1000, 64),
                                rng.integers(0, 500, 64)], 1).astype(np.int32))
    want = table.embed_fields(params, bufs, ids)

    st = TieredStore(np.asarray(params["memory"]), 1024, block=128,
                     stage_blocks=24)
    offs = np.asarray(cfg.table_offsets()[:-1], np.int32)
    gids = (np.asarray(ids) + offs[None, :]).reshape(-1)
    loc = scheme.locations(cfg, bufs, jnp.asarray(gids))
    st.stage(st.touched_blocks(loc)[0])
    tree = st.install({"memory": st.initial_compact()})
    tbufs = {**bufs, **st.batch_tier_buffers()}
    assert bke.resolve_backend(cfg, tree, scheme, tbufs) is bke.TIERED
    got = table.embed_fields(tree, tbufs, ids)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ------------------------------------------- end-to-end training parity

def test_tiered_training_parity_vs_resident_oracle():
    """The acceptance test: 25 adagrad steps over a 4x over-budget pool,
    re-tiering every 4 steps, must leave the (reconstructed) full pool AND
    the optimizer accumulator bit-identical to the fully-resident run —
    and the fit result carries the guard/exchange fields (PR satellite)
    plus the tier throughput stats."""
    cfg = _embed_cfg()
    table = EmbeddingTable(cfg)
    scheme = get_scheme(cfg.kind)
    bufs = table.make_buffers()
    params0 = {"embedding": table.init(jax.random.key(1))}
    m = int(params0["embedding"]["memory"].shape[0])
    offs = np.asarray(cfg.table_offsets()[:-1], np.int32)

    def raw_batch(step):
        r = np.random.default_rng(step)
        return {"ids": jnp.asarray(np.stack(
                    [r.integers(0, 1000, 64), r.integers(0, 500, 64)],
                    1).astype(np.int32)),
                "y": jnp.asarray(r.normal(size=(64, 2, 16)).astype(np.float32))}

    def make_loss(base_bufs):
        def loss(p, b):
            batch, tier = split_batch(b)
            e = table.embed_fields(p["embedding"], {**base_bufs, **tier},
                                   batch["ids"])
            l = jnp.mean((e - batch["y"]) ** 2)
            return l, {"l": l}
        return loss

    def fit(tier_ctrl):
        # real copies: the trainer donates params, and both fits (plus the
        # tier store's host mirror) start from the same initial pool
        params = jax.tree_util.tree_map(lambda x: jnp.array(x, copy=True),
                                        params0)
        if tier_ctrl is not None:
            params = {"embedding": dict(
                params["embedding"],
                memory=tier_ctrl.store.initial_compact())}
        tr = Trainer(TrainerConfig(total_steps=25, log_every=0),
                     make_loss(bufs), params, opt_lib.adagrad(0.1),
                     raw_batch, sparse_grads=False, tier=tier_ctrl)
        out = tr.fit(log=lambda s: None)
        return tr, out

    oracle, _ = fit(None)

    st = TieredStore(np.asarray(params0["embedding"]["memory"]), 1024,
                     block=128, stage_blocks=24)

    def plan_fn(batch):
        gids = (np.asarray(batch["ids"]) + offs[None, :]).reshape(-1)
        return scheme.locations(cfg, bufs, jnp.asarray(gids))

    ctrl = TierController(st, raw_batch, plan_fn, retier_every=4)
    tiered, out = fit(ctrl)
    assert st.stats["promoted"] > 0, "re-tiering never fired"

    # values: reconstructed full pool == resident pool, bitwise
    full = np.asarray(
        ctrl.export_params(tiered.params)["embedding"]["memory"])
    np.testing.assert_array_equal(
        full, np.asarray(oracle.params["embedding"]["memory"]))

    # moments: the adagrad accumulator migrated bit-exactly too
    (_, acc_c), = pool_leaf_paths(tiered.opt_state, st.compact_slots)
    (_, acc_o), = pool_leaf_paths(oracle.opt_state, m)
    name, = [k for k in st._host if k != "memory"]
    np.testing.assert_array_equal(st.full_pool(acc_c, name),
                                  np.asarray(acc_o))

    # result-dict satellite: guard/exchange fields + tier throughput stats
    for k in ("guard_enabled", "exchange", "tier_hot_rows", "tier_cold_rows",
              "tier_staged_blocks_per_step", "tier_host_fetch_bytes_per_step",
              "tier_promoted", "tier_demoted"):
        assert k in out, k
    assert out["tier_hot_rows"] == 1024
    assert out["tier_cold_rows"] == m - 1024
    assert out["exchange"] == "auto"
    assert out["tier_host_fetch_bytes_per_step"] > 0


def test_launcher_maybe_tier_is_genuinely_budget_bounded():
    """The launcher path must hand the store a batch-derived staging bound:
    the compact device pool (every leaf, stage region included) fits the
    --tier-budget-mb budget, so an over-budget pool actually saves HBM —
    and a budget that staging alone exhausts is refused, never silently
    over-allocated."""
    from repro.configs.base import get_config
    from repro.launch.train import MOMENT_LEAVES, _maybe_tier, _recsys_setup
    from repro.models import recsys

    arch = get_config("din")
    cfg = arch.make_model(None)
    gen, bufs, batch_fn, _ = _recsys_setup(arch, cfg, 300, 2)
    params = recsys.init(jax.random.key(0), cfg)
    m = int(params["embedding"]["memory"].shape[0])
    budget_mb = 32.0
    n_leaves = 1 + MOMENT_LEAVES[arch.optimizer]
    assert m * n_leaves * 4 > budget_mb * 2**20, "pool must be over budget"
    tiered, loss, ctrl = _maybe_tier(cfg, arch, params, bufs, batch_fn,
                                     budget_mb)
    assert ctrl is not None and loss is not None
    st = ctrl.store
    assert st.compact_slots < m, "compact pool must be smaller than the pool"
    assert st.stage_blocks < st.cold_blocks, "staging must be bounded"
    dev_bytes = n_leaves * st.compact_slots * 4
    assert dev_bytes <= budget_mb * 2**20, (dev_bytes, budget_mb * 2**20)
    assert tiered["embedding"]["memory"].shape == (st.compact_slots,)
    # one controller step stays within the staging bound it derived
    p, o, info = ctrl.pre_step(0, tiered, {})
    assert 0 < info["staged"] <= st.stage_blocks

    # a budget the stage regions alone exhaust is refused loudly: the
    # criteo pool (208 blocks) is smaller than one step's planned working
    # set, so no budget below its resident size can tier it
    arch_c = get_config("lma-dlrm-criteo")
    cfg_c = arch_c.make_model(None)
    gen, bufs_c, batch_fn_c, _ = _recsys_setup(arch_c, cfg_c, 300, 4)
    params_c = recsys.init(jax.random.key(0), cfg_c)
    with pytest.raises(SystemExit, match="stage regions alone"):
        _maybe_tier(cfg_c, arch_c, params_c, bufs_c, batch_fn_c, 0.5)


def test_controller_on_restore_drops_staged_rows():
    cfg = _embed_cfg()
    table = EmbeddingTable(cfg)
    st = TieredStore(np.asarray(table.init(jax.random.key(1))["memory"]),
                     1024, block=128, stage_blocks=24)
    st.stage(np.array([9, 10]))
    tree = st.install({"memory": st.initial_compact()})
    ctrl = TierController(st, lambda s: {}, lambda b: None)
    assert st._staged_ids is not None and st._staged_ids.size == 2
    ctrl.on_restore()
    assert st._staged_ids is None
    st.writeback(tree)                          # must be a clean no-op
    assert st.stats["writeback_bytes"] == 0
