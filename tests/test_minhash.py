"""Minwise hashing (paper section 3.3): P(collision) == Jaccard.

Includes hypothesis property tests over random set pairs.
"""
from __future__ import annotations

import numpy as np
import pytest

try:  # optional dev dep: only the property-based tests need it
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

import jax.numpy as jnp

from repro.core.hashing import UINT32_MAX
from repro.core.minhash import gather_ragged_sets, jaccard_from_sets, minhash_dense

from conftest import sets_with_jaccard, true_jaccard


def _sigs_for_sets(a: set, b: set, n_hashes: int, seed: int = 0):
    max_len = max(len(a), len(b))
    elems = np.zeros((2, max_len), np.uint32)
    mask = np.zeros((2, max_len), bool)
    for i, s in enumerate((a, b)):
        items = np.asarray(sorted(s), np.uint32)
        elems[i, : len(items)] = items
        mask[i, : len(items)] = True
    return np.asarray(minhash_dense(jnp.asarray(elems), jnp.asarray(mask),
                                    n_hashes, seed))


@pytest.mark.parametrize("j", [0.0, 0.2, 0.5, 0.8, 1.0])
def test_collision_probability_matches_jaccard(j):
    a, b = sets_with_jaccard(j, size=40)
    jt = true_jaccard(a, b)
    sigs = _sigs_for_sets(a, b, n_hashes=2048, seed=17)
    p_hat = float((sigs[0] == sigs[1]).mean())
    # binomial std with n=2048
    tol = 3.0 * np.sqrt(max(jt * (1 - jt), 0.01) / 2048) + 0.01
    assert abs(p_hat - jt) < tol, (p_hat, jt)


def test_identical_sets_collide_always():
    a = set(range(50))
    sigs = _sigs_for_sets(a, a, n_hashes=256)
    assert (sigs[0] == sigs[1]).all()


def test_empty_set_sentinel():
    elems = jnp.zeros((2, 8), jnp.uint32)
    mask = jnp.asarray([[True] * 8, [False] * 8])
    sigs = np.asarray(minhash_dense(elems, mask, 16, 0))
    assert (sigs[1] == np.uint32(UINT32_MAX)).all()
    assert not (sigs[0] == np.uint32(UINT32_MAX)).all()


def test_chunking_invariance():
    """Result must not depend on the scan chunk size."""
    rng = np.random.default_rng(3)
    elems = jnp.asarray(rng.integers(0, 2**32, (4, 12), dtype=np.uint32))
    mask = jnp.asarray(rng.random((4, 12)) < 0.8)
    a = np.asarray(minhash_dense(elems, mask, 33, 5, chunk=4))
    b = np.asarray(minhash_dense(elems, mask, 33, 5, chunk=16))
    c = np.asarray(minhash_dense(elems, mask, 33, 5, chunk=64))
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(a, c)


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(
        a=st.sets(st.integers(0, 5000), min_size=1, max_size=40),
        b=st.sets(st.integers(0, 5000), min_size=1, max_size=40),
    )
    def test_property_collision_rate_tracks_jaccard(a, b):
        """For arbitrary set pairs the empirical collision rate tracks J."""
        jt = true_jaccard(a, b)
        sigs = _sigs_for_sets(a, b, n_hashes=1024, seed=2)
        p_hat = float((sigs[0] == sigs[1]).mean())
        tol = 4.0 * np.sqrt(max(jt * (1 - jt), 0.02) / 1024) + 0.02
        assert abs(p_hat - jt) < tol
else:
    def test_property_collision_rate_tracks_jaccard():
        pytest.importorskip("hypothesis")


def test_gather_ragged_sets_roundtrip():
    flat = jnp.asarray(np.arange(20, dtype=np.uint32))
    offsets = jnp.asarray(np.array([0, 3, 3, 10, 20], np.int32))
    elems, mask = gather_ragged_sets(flat, offsets,
                                     jnp.asarray([0, 1, 2, 3]), max_len=8)
    elems, mask = np.asarray(elems), np.asarray(mask)
    np.testing.assert_array_equal(elems[0][mask[0]], [0, 1, 2])
    assert mask[1].sum() == 0                       # empty set
    np.testing.assert_array_equal(elems[2][mask[2]], np.arange(3, 10))
    assert mask[3].sum() == 8                       # truncated from 10 to max_len


def test_jaccard_from_sets_oracle():
    assert jaccard_from_sets(set(), set()) == 1.0
    assert jaccard_from_sets({1, 2}, {2, 3}) == pytest.approx(1 / 3)
